"""Paper Fig. 1: relative residual of A(16,k) x B(k,16) vs k, per method.

Reproduces the paper's headline accuracy result: the corrected methods
(fp16_halfhalf faithful reproduction; tcec_bf16x6 TPU-native) track FP32
SIMT accuracy across k, while uncorrected low precision and the 3-pass
bf16 variant sit orders of magnitude above."""
import numpy as np
import jax.numpy as jnp

from repro.core import policy_mm
from repro.core.matgen import relative_residual, urand
from .common import emit, record

KS = [32, 128, 512, 2048, 8192]
METHODS = ["fp32", "bf16", "tcec_bf16x3", "tcec_bf16x6",
           "fp16_markidis", "fp16_halfhalf"]


def run():
    rows = []
    for k in KS:
        errs = {}
        for m in METHODS:
            vals = []
            for seed in range(4):  # paper averages over 8 seeds; 4 suffices
                a = urand((16, k), seed=seed * 17 + k)
                b = urand((k, 16), seed=seed * 31 + k + 1)
                c = policy_mm(jnp.asarray(a), jnp.asarray(b), m)
                vals.append(relative_residual(np.asarray(c), a, b))
            errs[m] = float(np.mean(vals))
            record(f"fig1/k{k}/{m}/residual", errs[m], unit="rel",
                   higher_is_better=False)
        rows.append([k] + [f"{errs[m]:.2e}" for m in METHODS])
    checks = []
    # invariants from the paper's figure
    last = {m: float(rows[-1][1 + METHODS.index(m)].replace("e", "E"))
            for m in METHODS}
    checks.append(("tcec_bf16x6 ~= fp32", last["tcec_bf16x6"] < 2 * last["fp32"]))
    checks.append(("halfhalf ~= fp32", last["fp16_halfhalf"] < 2 * last["fp32"]))
    checks.append(("bf16 >> fp32", last["bf16"] > 50 * last["fp32"]))
    notes = "; ".join(f"{n}: {'PASS' if ok else 'FAIL'}" for n, ok in checks)
    emit("fig1_accuracy", "Fig.1 — relative residual vs k (mean of 4 seeds)",
         ["k"] + METHODS, rows, notes)
    return all(ok for _, ok in checks)
