"""Paper Fig. 14/15 + Table 5, re-derived for the TPU v5e target.

No TPU wall clock exists in this container, so this benchmark reports the
same analytic roofline the paper uses for its Fig. 15: per GEMM size, the
three roofline terms of the TCEC kernel (bf16 MXU passes / f32 HBM traffic)
and the effective-peak ceiling ``MXU_peak / passes`` — the TPU analogue of
the paper's ``312/3 = 104 TFlop/s`` (fp16) and ``156/3 = 52`` (tf32)
upper bounds. Interpret-mode numerics of the same kernel are validated in
tests/test_kernels.py; fig1 above shows the accuracy side."""
import numpy as np

from repro.core.policy import get_policy
from repro.kernels import pick_block, vmem_bytes
from .common import emit

PEAK_BF16 = 197e12     # per-chip MXU
PEAK_F32_VPU = 197e12 / 8   # fp32 on VPU, ~1/8 of MXU (structural estimate)
HBM = 819e9


def terms(m, n, k, policy_name):
    pol = get_policy(policy_name)
    passes = pol.passes
    flops = 2.0 * m * n * k * passes
    # fused kernel: read f32 A,B once, write f32 C once (paper's "no extra
    # footprint" property)
    bts = 4.0 * (m * k + k * n + m * n)
    return flops / PEAK_BF16, bts / HBM, passes


def run():
    rows = []
    ok = True
    for size in [1024, 4096, 16384]:
        for polname in ["tcec_bf16x3", "tcec_bf16x6"]:
            c, b, passes = terms(size, size, size, polname)
            eff_peak = PEAK_BF16 / passes
            t = max(c, b)
            tflops = 2.0 * size ** 3 / t / 1e12
            blk = pick_block(size, size, size, polname)
            rows.append([size, polname, passes,
                         f"{eff_peak/1e12:.1f}", f"{c*1e3:.2f}",
                         f"{b*1e3:.3f}", f"{tflops:.1f}",
                         f"{tflops*1e12/PEAK_F32_VPU:.1f}x",
                         f"{blk}"])
            if size >= 4096:
                # the paper's headline structure: emulated-fp32 GEMM beats
                # the fp32 (non-MXU) peak
                ok &= tflops * 1e12 > PEAK_F32_VPU
    emit("fig14_throughput",
         "Fig.14/15 — analytic TPU-v5e roofline of the TCEC kernel "
         "(per-chip, square GEMM)",
         ["size", "policy", "passes", "eff-peak TF/s", "compute ms",
          "memory ms", "achievable TF/s", "vs fp32-VPU peak", "block"],
         rows,
         "achievable fp32-GEMM throughput exceeds the non-MXU fp32 peak "
         f"for large GEMMs (the paper's headline claim, TPU form): "
         f"{'PASS' if ok else 'FAIL'}")
    return ok
