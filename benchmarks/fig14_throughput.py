"""Paper Fig. 14/15 + Table 5, re-derived for the TPU v5e target —
GEMM mode (default) plus an ``attention`` mode for the fused
flash-attention kernel.

CLI (the ``--smoke`` path runs in CI as the attention dispatch gate):

    PYTHONPATH=src python -m benchmarks.fig14_throughput            # gemm
    PYTHONPATH=src python -m benchmarks.fig14_throughput attention
    PYTHONPATH=src python -m benchmarks.fig14_throughput attention --smoke


No TPU wall clock exists in this container, so this benchmark reports the
same analytic roofline the paper uses for its Fig. 15: per GEMM size and
per *dispatch path*, the roofline terms of the corrected GEMM and the
effective-peak ceiling ``MXU_peak / passes`` — the TPU analogue of the
paper's ``312/3 = 104 TFlop/s`` (fp16) and ``156/3 = 52`` (tf32) bounds.

Three paths are compared per problem:

  * ``fused+tuned``  — the Pallas kernel with the autotuner's block
    (measured winner if a cache exists, heuristic otherwise): f32 A/B read
    once, C written once (the paper's "no extra footprint" property);
  * ``fused+heur``   — same kernel, static heuristic block (what you get
    with an empty autotune cache);
  * ``xla-expand``   — the term-expansion fallback: bf16 split terms are
    materialized to HBM and re-read per pass, and per-group partial
    accumulators round-trip HBM — the traffic the fusion eliminates.

Interpret-mode numerics of the same kernel are validated in
tests/test_kernels.py and tests/test_dispatch.py; fig1 shows accuracy."""
import numpy as np

from repro import get_policy, tuning
from .common import emit, record

PEAK_BF16 = 197e12     # per-chip MXU
PEAK_F32_VPU = 197e12 / 8   # fp32 on VPU, ~1/8 of MXU (structural estimate)
HBM = 819e9


def fused_bytes(m, n, k, pol):
    """Fused kernel: read f32 A,B once, write f32 C once."""
    return 4.0 * (m * k + k * n + m * n)


def xla_bytes(m, n, k, pol):
    """Term-expansion fallback traffic model: split materialization (f32
    read + n_splits bf16 writes per operand), per-pass bf16 term re-reads,
    and per-scale-group f32 partial-accumulator round trips + epilogue."""
    groups = len(pol.groups)
    split_io = (4.0 + 2.0 * pol.n_splits) * (m * k + k * n)
    pass_reads = 2.0 * (m * k + k * n) * pol.passes
    acc_io = 4.0 * m * n * (2.0 * groups + 1.0)
    return split_io + pass_reads + acc_io


def roofline(m, n, k, policy_name, bytes_fn):
    pol = get_policy(policy_name)
    flops = 2.0 * m * n * k * pol.passes
    t = max(flops / PEAK_BF16, bytes_fn(m, n, k, pol) / HBM)
    return 2.0 * m * n * k / t / 1e12    # achievable TF/s (useful FLOPs)


def run():
    rows = []
    ok = True
    for size in [1024, 4096, 16384]:
        for polname in ["tcec_bf16x3", "tcec_bf16x6"]:
            pol = get_policy(polname)
            eff_peak = PEAK_BF16 / pol.passes
            heur_blk = tuning.heuristic_block(size, size, size, polname)
            tuned_blk, meta = tuning.autotune(1, size, size, size, polname)
            tf_fused = roofline(size, size, size, polname, fused_bytes)
            tf_xla = roofline(size, size, size, polname, xla_bytes)
            paths = [("fused+heur", heur_blk, tf_fused),
                     ("xla-expand", "-", tf_xla)]
            if meta.get("ms") is not None:
                # only when a measured (or cached-measured) winner exists is
                # there a tuned row distinct from the heuristic baseline —
                # source alone can't tell: the in-memory LRU also caches
                # heuristic picks (ms=None) within a process
                paths.insert(0, ("fused+tuned", tuned_blk, tf_fused))
            for path, blk, tf in paths:
                rows.append([size, polname, path, f"{blk}",
                             f"{eff_peak/1e12:.1f}", f"{tf:.1f}",
                             f"{tf*1e12/PEAK_F32_VPU:.1f}x",
                             f"{tf_fused/tf_xla:.2f}x" if path != "xla-expand"
                             else "1.00x"])
                record(f"gemm/{size}/{polname}/{path}/tflops", tf,
                       unit="TF/s")
            record(f"gemm/{size}/{polname}/fused_speedup",
                   tf_fused / tf_xla, unit="x")
            if size >= 4096:
                # the paper's headline structure: emulated-fp32 GEMM beats
                # the fp32 (non-MXU) peak — on the fused path
                ok &= tf_fused * 1e12 > PEAK_F32_VPU
                # and fusion must strictly beat the term-expansion traffic
                ok &= tf_fused >= tf_xla
    emit("fig14_throughput",
         "Fig.14/15 — analytic TPU-v5e roofline: tuned/heuristic fused "
         "kernel vs XLA term-expansion (per-chip, square GEMM)",
         ["size", "policy", "path", "block", "eff-peak TF/s",
          "achievable TF/s", "vs fp32-VPU peak", "fused speedup"],
         rows,
         "achievable fp32-GEMM throughput exceeds the non-MXU fp32 peak "
         f"for large GEMMs on the fused path (paper's headline, TPU form): "
         f"{'PASS' if ok else 'FAIL'}")
    return ok


# ------------------------------------------------------- attention mode
#
# Three ways to run the same corrected-precision attention:
#
#   * ``fused-flash``  — kernels/tcec_attention.py: Q/K/V read once, O
#     written once; scores/probs live only in VMEM (splits in-register);
#   * ``pdot-blocked`` — models/layers.py::blocked_attention: per KV chunk
#     the QK^T and P·V policy GEMMs are separate kernels, so the chunk's
#     probs tensor and the per-pass bf16 split terms round-trip HBM;
#   * ``xla-sdpa``     — models/layers.py::mha: the full (S, T) scores AND
#     probs tensors are materialized (written + re-read), per head.

def _attn_flops(S, T, H, hd, hdv, passes, causal):
    f = 2.0 * H * S * T * (hd + hdv) * passes
    return f / 2.0 if causal else f


def fused_attn_bytes(S, T, H, Hkv, hd, hdv, pol):
    """Fused kernel including its wrapper's layout pass: Q/K/V are read,
    written transposed to the kernel layout, and re-read by the kernel
    (3 passes each); O is written by the kernel, then transposed back
    (3 passes).  The (S, T)-sized scores/probs never travel — the term
    that dominates every unfused path below."""
    ops = S * H * hd + T * Hkv * (hd + hdv) + S * H * hdv
    return 4.0 * 3.0 * ops


def blocked_attn_bytes(S, T, H, Hkv, hd, hdv, pol):
    """pdot composition: operand traffic + per-pass bf16 split-term reads
    for both GEMMs + the f32 probs tensor round-tripping between them."""
    ops = 4.0 * (S * H * hd + T * Hkv * (hd + hdv) + S * H * hdv)
    splits = 2.0 * pol.n_splits * (S * H * hd + T * Hkv * (hd + hdv))
    split_reads = 2.0 * pol.passes * (S * H * hd + T * Hkv * (hd + hdv))
    probs = 2.0 * 4.0 * H * S * T * (1.0 + pol.n_splits / 2.0)
    return ops + splits + split_reads + probs


def sdpa_attn_bytes(S, T, H, Hkv, hd, hdv, pol):
    """Materialized mha: blocked traffic + scores written/read twice more
    (raw scores -> masked/softcapped scores -> softmax probs)."""
    return blocked_attn_bytes(S, T, H, Hkv, hd, hdv, pol) \
        + 4.0 * 4.0 * H * S * T


def _attn_roofline(S, T, H, Hkv, hd, hdv, policy_name, bytes_fn, causal):
    pol = get_policy(policy_name)
    flops = _attn_flops(S, T, H, hd, hdv, pol.passes, causal)
    useful = flops / pol.passes
    t = max(flops / PEAK_BF16, bytes_fn(S, T, H, Hkv, hd, hdv, pol) / HBM)
    return useful / t / 1e12


def _smoke_check():
    """Actually run the fused kernel (interpret mode) against the model's
    own fallback — the CI gate for attention-dispatch regressions."""
    import numpy as np
    import jax.numpy as jnp
    import repro
    from repro import numerics
    from repro.models import layers as L

    class Cfg:
        mix_policy = "tcec_bf16x6"
        attn_softcap = None

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)).astype(np.float32))
    pos = jnp.arange(256, dtype=jnp.int32)[None]
    ref = L.mha(q, k, v, Cfg, pos, pos, causal=True, window=0)
    fused = repro.attention(q, k, v, policy="tcec_bf16x6", q_pos=pos,
                            k_pos=pos, causal=True, force=True,
                            interpret=True, min_dim=0,
                            attn_block=(128, 128))
    ok = bool(np.allclose(np.asarray(fused), np.asarray(ref),
                          rtol=2e-6, atol=2e-6))
    with numerics.use(enabled=False, force=True, interpret=True, min_dim=0):
        # the escape hatch must restore the pure-XLA path bit for bit
        hatch = L.sdpa(q, k, v, Cfg, pos, pos, causal=True, window=0)
    ok &= bool(np.array_equal(np.asarray(hatch), np.asarray(ref)))
    return ok


def run_attention(smoke: bool = False):
    shapes = [(2048, 32, 8, 128), (8192, 32, 8, 128), (32768, 32, 8, 128)]
    if smoke:
        shapes = shapes[:1]
    rows = []
    ok = True
    polname = "tcec_bf16x6"
    for S, H, Hkv, hd in shapes:
        paths = [("fused-flash", fused_attn_bytes),
                 ("pdot-blocked", blocked_attn_bytes),
                 ("xla-sdpa", sdpa_attn_bytes)]
        tf = {name: _attn_roofline(S, S, H, Hkv, hd, hd, polname, fn, True)
              for name, fn in paths}
        for name, _ in paths:
            rows.append([S, H, Hkv, hd, name, f"{tf[name]:.1f}",
                         f"{tf['fused-flash'] / tf[name]:.2f}x"])
            record(f"attn/{S}/{polname}/{name}/tflops", tf[name],
                   unit="TF/s")
        # fusion must strictly beat both unfused traffic models, and the
        # long-prefill cells must clear the non-MXU fp32 peak
        ok &= tf["fused-flash"] >= tf["pdot-blocked"] >= tf["xla-sdpa"]
        if S >= 8192:
            ok &= tf["fused-flash"] * 1e12 > PEAK_F32_VPU
    if smoke:
        parity = _smoke_check()
        record("attn/smoke/kernel_vs_fallback_parity", float(parity))
        ok &= parity
        note = ("smoke: fused kernel (interpret) vs mha fallback parity + "
                f"escape hatch: {'PASS' if parity else 'FAIL'}; ")
        # smoke truncates to S=2048, so the long-prefill VPU-peak clause
        # never runs — don't claim it
        claim = "fused >= pdot-blocked >= xla-sdpa"
    else:
        note = ""
        claim = ("fused >= pdot-blocked >= xla-sdpa and long-prefill beats "
                 "the fp32-VPU peak")
    emit("fig14_attention",
         "Fig.14/15 (attention form) — analytic TPU-v5e roofline: fused "
         "flash-attention kernel vs pdot composition vs materialized sdpa "
         f"(causal, {polname}, per batch element)",
         ["S=T", "H", "Hkv", "hd", "path", "achievable TF/s",
          "fused speedup"],
         rows,
         note + f"{claim}: {'PASS' if ok else 'FAIL'}")
    return ok


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("mode", nargs="?", default="gemm",
                    choices=["gemm", "attention", "all"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + a real interpret-mode kernel-vs-"
                         "fallback parity check (the CI gate)")
    args = ap.parse_args(argv)
    ok = True
    if args.mode in ("gemm", "all"):
        ok &= run()
    if args.mode in ("attention", "all"):
        ok &= run_attention(smoke=args.smoke)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
