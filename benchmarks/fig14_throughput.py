"""Paper Fig. 14/15 + Table 5, re-derived for the TPU v5e target.

No TPU wall clock exists in this container, so this benchmark reports the
same analytic roofline the paper uses for its Fig. 15: per GEMM size and
per *dispatch path*, the roofline terms of the corrected GEMM and the
effective-peak ceiling ``MXU_peak / passes`` — the TPU analogue of the
paper's ``312/3 = 104 TFlop/s`` (fp16) and ``156/3 = 52`` (tf32) bounds.

Three paths are compared per problem:

  * ``fused+tuned``  — the Pallas kernel with the autotuner's block
    (measured winner if a cache exists, heuristic otherwise): f32 A/B read
    once, C written once (the paper's "no extra footprint" property);
  * ``fused+heur``   — same kernel, static heuristic block (what you get
    with an empty autotune cache);
  * ``xla-expand``   — the term-expansion fallback: bf16 split terms are
    materialized to HBM and re-read per pass, and per-group partial
    accumulators round-trip HBM — the traffic the fusion eliminates.

Interpret-mode numerics of the same kernel are validated in
tests/test_kernels.py and tests/test_dispatch.py; fig1 shows accuracy."""
import numpy as np

from repro.core.policy import get_policy
from repro.kernels import tuning
from .common import emit

PEAK_BF16 = 197e12     # per-chip MXU
PEAK_F32_VPU = 197e12 / 8   # fp32 on VPU, ~1/8 of MXU (structural estimate)
HBM = 819e9


def fused_bytes(m, n, k, pol):
    """Fused kernel: read f32 A,B once, write f32 C once."""
    return 4.0 * (m * k + k * n + m * n)


def xla_bytes(m, n, k, pol):
    """Term-expansion fallback traffic model: split materialization (f32
    read + n_splits bf16 writes per operand), per-pass bf16 term re-reads,
    and per-scale-group f32 partial-accumulator round trips + epilogue."""
    groups = len(pol.groups)
    split_io = (4.0 + 2.0 * pol.n_splits) * (m * k + k * n)
    pass_reads = 2.0 * (m * k + k * n) * pol.passes
    acc_io = 4.0 * m * n * (2.0 * groups + 1.0)
    return split_io + pass_reads + acc_io


def roofline(m, n, k, policy_name, bytes_fn):
    pol = get_policy(policy_name)
    flops = 2.0 * m * n * k * pol.passes
    t = max(flops / PEAK_BF16, bytes_fn(m, n, k, pol) / HBM)
    return 2.0 * m * n * k / t / 1e12    # achievable TF/s (useful FLOPs)


def run():
    rows = []
    ok = True
    for size in [1024, 4096, 16384]:
        for polname in ["tcec_bf16x3", "tcec_bf16x6"]:
            pol = get_policy(polname)
            eff_peak = PEAK_BF16 / pol.passes
            heur_blk = tuning.heuristic_block(size, size, size, polname)
            tuned_blk, meta = tuning.autotune(1, size, size, size, polname)
            tf_fused = roofline(size, size, size, polname, fused_bytes)
            tf_xla = roofline(size, size, size, polname, xla_bytes)
            paths = [("fused+heur", heur_blk, tf_fused),
                     ("xla-expand", "-", tf_xla)]
            if meta["source"] != "heuristic":
                # only when a measured (or cached-measured) winner exists is
                # there a tuned row distinct from the heuristic baseline
                paths.insert(0, ("fused+tuned", tuned_blk, tf_fused))
            for path, blk, tf in paths:
                rows.append([size, polname, path, f"{blk}",
                             f"{eff_peak/1e12:.1f}", f"{tf:.1f}",
                             f"{tf*1e12/PEAK_F32_VPU:.1f}x",
                             f"{tf_fused/tf_xla:.2f}x" if path != "xla-expand"
                             else "1.00x"])
            if size >= 4096:
                # the paper's headline structure: emulated-fp32 GEMM beats
                # the fp32 (non-MXU) peak — on the fused path
                ok &= tf_fused * 1e12 > PEAK_F32_VPU
                # and fusion must strictly beat the term-expansion traffic
                ok &= tf_fused >= tf_xla
    emit("fig14_throughput",
         "Fig.14/15 — analytic TPU-v5e roofline: tuned/heuristic fused "
         "kernel vs XLA term-expansion (per-chip, square GEMM)",
         ["size", "policy", "path", "block", "eff-peak TF/s",
          "achievable TF/s", "vs fp32-VPU peak", "fused speedup"],
         rows,
         "achievable fp32-GEMM throughput exceeds the non-MXU fp32 peak "
         f"for large GEMMs on the fused path (paper's headline, TPU form): "
         f"{'PASS' if ok else 'FAIL'}")
    return ok
