"""Shared benchmark helpers: table formatting, a blocking timer, and the
machine-readable snapshot recorder behind ``benchmarks/run.py --snapshot``
/ ``benchmarks/compare.py`` (see benchmarks/README.md §Snapshots)."""
from __future__ import annotations

import json
import math
import os
import time

import jax

from repro.numerics import env_value

OUT_DIR = env_value("REPRO_BENCH_OUT")

SCHEMA_VERSION = 1


def table(title: str, headers: list[str], rows: list[list]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    def fmt(cells):
        return " | ".join(str(c).ljust(w) for c, w in zip(cells, widths))
    lines = [f"## {title}", fmt(headers),
             "-|-".join("-" * w for w in widths)]
    lines += [fmt(r) for r in rows]
    return "\n".join(lines)


def emit(name: str, title: str, headers, rows, notes: str = ""):
    txt = table(title, headers, rows)
    if notes:
        txt += f"\n{notes}"
    print(txt + "\n", flush=True)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump({"title": title, "headers": headers,
                   "rows": [[str(c) for c in r] for r in rows],
                   "notes": notes}, f, indent=1)
    return txt


# ------------------------------------------------------- blocking timer

def block(x):
    """Wait for every async leaf of a pytree; returns x unchanged."""
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return x


def timed(fn, *args, reps: int = 3, warmup: int = 1):
    """Time ``fn(*args)`` with ``reps`` blocking reps after ``warmup``
    untimed calls (compile + cache warm).

    jax dispatch is async: an unblocked wall-clock delta times the
    *enqueue*, not the compute, so every call — warmup included — blocks
    on the output before the clock is read.  Returns ``(out, mean_s,
    samples)``; the per-rep ``samples`` feed :func:`record_timed` so
    ``compare.py`` gets a real noise estimate instead of a guess.
    """
    out = None
    for _ in range(max(0, warmup)):
        out = block(fn(*args))
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        out = block(fn(*args))
        samples.append(time.perf_counter() - t0)
    return out, sum(samples) / len(samples), samples


def _stdev(samples) -> float:
    if len(samples) < 2:
        return 0.0
    mean = sum(samples) / len(samples)
    return math.sqrt(sum((s - mean) ** 2 for s in samples)
                     / (len(samples) - 1))


def noise_probe(reps: int = 5) -> float:
    """Relative wall-clock jitter (std/mean) of a tiny jitted op — the
    environment's timing-noise fingerprint recorded in every snapshot."""
    import jax.numpy as jnp
    f = jax.jit(lambda x: x @ x)
    x = jnp.ones((128, 128), jnp.float32)
    _, mean, samples = timed(f, x, reps=reps, warmup=2)
    return _stdev(samples) / mean if mean else 0.0


# --------------------------------------------------- snapshot recorder
#
# run.py --snapshot brackets each bench with begin_snapshot()/
# end_snapshot(); bench modules call record()/record_timed() as they go
# (no-ops outside snapshot mode, so plain runs cost nothing).

_METRICS: dict | None = None


def snapshot_active() -> bool:
    return _METRICS is not None


def begin_snapshot():
    global _METRICS
    _METRICS = {}


def end_snapshot() -> dict:
    global _METRICS
    metrics, _METRICS = _METRICS or {}, None
    return metrics


def record(name: str, value, *, unit: str = "", kind: str = "analytic",
           higher_is_better: bool = True, noise: float = 0.0):
    """Record one numeric snapshot metric (no-op outside snapshot mode).

    ``kind="analytic"`` — deterministic (model-derived or counted):
    compare.py gates it at a tight relative floor and the determinism
    test requires it bit-identical across runs.  ``kind="measured"`` —
    wall-clock derived: gated against max(noise band, measured floor)
    and excluded from determinism checks.
    """
    if _METRICS is None:
        return
    assert kind in ("analytic", "measured"), kind
    if not math.isfinite(float(value)):
        # Infinity/NaN would serialize as nonstandard JSON and poison
        # every future comparison of this metric — fail at the source
        raise ValueError(f"non-finite snapshot metric {name}={value!r}")
    _METRICS[name] = {"value": float(value), "unit": unit, "kind": kind,
                      "higher_is_better": bool(higher_is_better),
                      "noise": float(noise)}


def record_timed(name: str, samples, *, unit: str = "s",
                 higher_is_better: bool = False, transform=None):
    """Record a measured metric from :func:`timed` per-rep samples.

    ``transform`` maps mean seconds to the reported value (e.g.
    ``lambda s: toks / s`` for tok/s); the relative jitter of the raw
    samples carries through as the metric's noise.
    """
    mean = sum(samples) / len(samples)
    value = transform(mean) if transform is not None else mean
    rel = _stdev(samples) / mean if mean else 0.0
    record(name, value, unit=unit, kind="measured",
           higher_is_better=higher_is_better, noise=abs(value) * rel)
