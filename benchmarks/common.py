"""Shared benchmark helpers: table formatting + result registry."""
from __future__ import annotations

import json
import os
import time

from repro.numerics import env_value

OUT_DIR = env_value("REPRO_BENCH_OUT")


def table(title: str, headers: list[str], rows: list[list]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    def fmt(cells):
        return " | ".join(str(c).ljust(w) for c, w in zip(cells, widths))
    lines = [f"## {title}", fmt(headers),
             "-|-".join("-" * w for w in widths)]
    lines += [fmt(r) for r in rows]
    return "\n".join(lines)


def emit(name: str, title: str, headers, rows, notes: str = ""):
    txt = table(title, headers, rows)
    if notes:
        txt += f"\n{notes}"
    print(txt + "\n", flush=True)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump({"title": title, "headers": headers,
                   "rows": [[str(c) for c in r] for r in rows],
                   "notes": notes}, f, indent=1)
    return txt


def timed(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    return out, (time.time() - t0) / reps
