"""Regression gate over persisted benchmark snapshots.

``run.py --snapshot`` writes one ``BENCH_<name>.json`` per bench
(committed at the repo root as the per-PR throughput trajectory); this
module diffs a regenerated candidate set against those baselines and
exits nonzero when any metric regressed beyond its noise band:

    PYTHONPATH=src python -m benchmarks.run --snapshot \\
        --snapshot-dir experiments/bench/snapshots
    PYTHONPATH=src python -m benchmarks.compare \\
        --baseline . --candidate experiments/bench/snapshots

Per-metric band (see benchmarks/README.md §Noise bands):

    band = max(sigmas * max(noise_base, noise_cand),
               floor * |base value|, 1e-12)

where ``floor`` is ``--rel-floor`` (default 2%) for ``analytic``
metrics and ``--measured-floor`` (default 50%) for ``measured``
wall-clock metrics, and ``noise`` is the per-rep jitter recorded by
``common.timed``.  A delta in the bad direction beyond the band is a
regression (exit 1); improvements and within-band drift pass; a metric
present on only one side is reported but never gates (kernels and
tuning caches legitimately add/remove rows); a missing baseline file is
a clean first-run pass.  Measured metrics only gate when baseline and
candidate ran on the same jax backend — cross-machine wall clock is not
comparable.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DEFAULT_SIGMAS = 3.0
DEFAULT_REL_FLOOR = 0.02
DEFAULT_MEASURED_FLOOR = 0.50
ABS_FLOOR = 1e-12

# statuses that never flip the exit code
NON_GATING = ("ok", "improved", "added", "removed", "ungated")


def load_snapshot(path: str) -> dict:
    with open(path) as f:
        snap = json.load(f)
    for key in ("bench", "metrics", "env"):
        if key not in snap:
            raise ValueError(f"{path}: not a BENCH snapshot (missing "
                             f"{key!r})")
    return snap


def band(base: dict, cand: dict, *, sigmas: float = DEFAULT_SIGMAS,
         rel_floor: float = DEFAULT_REL_FLOOR,
         measured_floor: float = DEFAULT_MEASURED_FLOOR) -> float:
    floor = (measured_floor if base.get("kind") == "measured"
             else rel_floor)
    return max(sigmas * max(base.get("noise", 0.0), cand.get("noise", 0.0)),
               floor * abs(base["value"]), ABS_FLOOR)


def compare_metrics(base: dict, cand: dict, *, sigmas=DEFAULT_SIGMAS,
                    rel_floor=DEFAULT_REL_FLOOR,
                    measured_floor=DEFAULT_MEASURED_FLOOR,
                    gate_measured: bool = True) -> list[dict]:
    """Metric-by-metric findings for two ``metrics`` dicts.

    Statuses: ``ok`` (within band), ``improved``, ``regression``,
    ``ungated`` (would regress but measured gating is off),
    ``added`` / ``removed`` (present on one side only).
    """
    findings = []
    for name in sorted(set(base) | set(cand)):
        if name not in base:
            findings.append({"metric": name, "status": "added",
                             "cand": cand[name]["value"]})
            continue
        if name not in cand:
            findings.append({"metric": name, "status": "removed",
                             "base": base[name]["value"]})
            continue
        b, c = base[name], cand[name]
        w = band(b, c, sigmas=sigmas, rel_floor=rel_floor,
                 measured_floor=measured_floor)
        delta = c["value"] - b["value"]
        if not b.get("higher_is_better", True):
            delta = -delta          # now: positive delta == better
        if delta < -w:
            status = "regression"
            if b.get("kind") == "measured" and not gate_measured:
                status = "ungated"
        elif delta > w:
            status = "improved"
        else:
            status = "ok"
        findings.append({"metric": name, "status": status,
                         "base": b["value"], "cand": c["value"],
                         "band": w, "delta": delta})
    return findings


def compare_snapshots(base_snap: dict, cand_snap: dict,
                      **kw) -> tuple[bool, list[dict]]:
    """Compare two loaded snapshots; returns ``(passed, findings)``."""
    same_backend = (base_snap.get("env", {}).get("backend")
                    == cand_snap.get("env", {}).get("backend"))
    kw.setdefault("gate_measured", same_backend)
    findings = compare_metrics(base_snap.get("metrics", {}),
                               cand_snap.get("metrics", {}), **kw)
    if base_snap.get("ok", True) and not cand_snap.get("ok", True):
        findings.insert(0, {"metric": "<bench claim>",
                            "status": "regression",
                            "base": 1.0, "cand": 0.0, "band": 0.0,
                            "delta": -1.0})
    passed = all(f["status"] in NON_GATING for f in findings)
    return passed, findings


def _fmt(v) -> str:
    return f"{v:.6g}" if isinstance(v, float) else str(v)


def report(name: str, findings: list[dict], verbose: bool = False):
    counts: dict[str, int] = {}
    for f in findings:
        counts[f["status"]] = counts.get(f["status"], 0) + 1
    summary = ", ".join(f"{v} {k}" for k, v in sorted(counts.items()))
    print(f"  {name}: {summary or 'no shared metrics'}")
    for f in findings:
        if f["status"] == "ok" and not verbose:
            continue
        parts = [f"    [{f['status']:>10}] {f['metric']}"]
        if "base" in f and "cand" in f:
            parts.append(f"{_fmt(f['base'])} -> {_fmt(f['cand'])} "
                         f"(band {_fmt(f['band'])})")
        elif "cand" in f:
            parts.append(f"-> {_fmt(f['cand'])}")
        elif "base" in f:
            parts.append(f"{_fmt(f['base'])} -> (gone)")
        print(" ".join(parts))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--baseline", default=".",
                    help="dir holding the committed BENCH_*.json")
    ap.add_argument("--candidate", default="experiments/bench/snapshots",
                    help="dir holding the regenerated snapshots")
    ap.add_argument("--sigmas", type=float, default=DEFAULT_SIGMAS)
    ap.add_argument("--rel-floor", type=float, default=DEFAULT_REL_FLOOR,
                    help="relative band floor for analytic metrics")
    ap.add_argument("--measured-floor", type=float,
                    default=DEFAULT_MEASURED_FLOOR,
                    help="relative band floor for measured (wall-clock) "
                         "metrics")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print within-band metrics too")
    args = ap.parse_args(argv)

    cand_files = sorted(glob.glob(os.path.join(args.candidate,
                                               "BENCH_*.json")))
    if not cand_files:
        print(f"compare: no BENCH_*.json under {args.candidate!r} — "
              "run `python -m benchmarks.run --snapshot` first")
        return 2
    failed = []
    for cf in cand_files:
        fname = os.path.basename(cf)
        bf = os.path.join(args.baseline, fname)
        if not os.path.exists(bf):
            print(f"  {fname}: no committed baseline — first-run pass "
                  "(commit the regenerated snapshot)")
            continue
        passed, findings = compare_snapshots(
            load_snapshot(bf), load_snapshot(cf), sigmas=args.sigmas,
            rel_floor=args.rel_floor, measured_floor=args.measured_floor)
        report(fname, findings, verbose=args.verbose)
        if not passed:
            failed.append(fname)
    if failed:
        print(f"compare: REGRESSION in {', '.join(failed)} — if the "
              "change is intentional, regenerate and commit the "
              "baselines (benchmarks/README.md §Refreshing baselines)")
        return 1
    print(f"compare: {len(cand_files)} snapshot(s) pass")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
