"""Paper Fig. 5: swap the accumulator rounding of the simulated matrix unit
— RN matches SGEMM, RZ matches Markidis ==> the TC-internal RZ is the error
source, motivating the paper's accumulate-outside fix."""
import numpy as np
import jax.numpy as jnp

from repro.core import policy_mm
from repro.core.accum import markidis_gemm_sim
from repro.core.matgen import relative_residual, urand
from .common import emit, record


def run():
    rows = []
    ok = True
    for k in [256, 1024, 4096]:
        a = urand((16, k), seed=k + 7)
        b = urand((k, 16), seed=k + 8)
        r_rn = relative_residual(markidis_gemm_sim(a, b, "rn"), a, b)
        r_rz = relative_residual(markidis_gemm_sim(a, b, "rz"), a, b)
        r_32 = relative_residual(
            np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), "fp32")), a, b)
        rows.append([k, f"{r_32:.2e}", f"{r_rn:.2e}", f"{r_rz:.2e}"])
        for tag, r in [("fp32", r_32), ("mma_rn", r_rn), ("mma_rz", r_rz)]:
            record(f"fig5/k{k}/{tag}/residual", r, unit="rel",
                   higher_is_better=False)
        if k >= 1024:
            ok &= (r_rn <= 3 * r_32) and (r_rz > 5 * r_rn)
    emit("fig5_rounding",
         "Fig.5 — Markidis split on mma_rn vs mma_rz accumulators",
         ["k", "fp32", "mma_rn", "mma_rz"], rows,
         f"rn==sgemm and rz>>rn at k>=1024: {'PASS' if ok else 'FAIL'}")
    return ok
