"""Serving throughput: continuous batching + paged KV cache vs the dense
legacy loop.

  PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke]
  PYTHONPATH=src python -m benchmarks.run serving          # smoke mode

Two claims, measured:

  1. **throughput scales with in-flight requests** — the engine decodes
     every resident slot in one jitted step, so tok/s grows with slot
     count while the dense path pays a full same-length batch or nothing;
  2. **parity is free** — with the paged kernel hatch closed, greedy
     engine output is token-identical to the dense reference (the
     ``--smoke`` gate CI runs), and the interpret-mode paged kernel agrees
     with the engine's gather fallback.

Numbers on CPU are for *shape* (scaling trend), not speed — kernels run
interpreted off-TPU.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import get_model
from .common import emit, record, record_timed, timed

ARCH = "qwen3-0.6b"


def _requests(cfg, n, seed=0, mixed=True):
    rng = np.random.default_rng(seed)
    lens = (rng.integers(4, 17, n) if mixed else np.full(n, 8))
    return [rng.integers(0, cfg.vocab_size, int(l)) for l in lens]


def _engine_run(cfg, params, prompts, max_slots, max_tokens=8,
                reps=1, warmup=0):
    """Run a fresh engine over ``prompts``; per-rep wall times come from
    the blocking timer (greedy + per-request seeds, so every rep yields
    identical tokens)."""
    from repro.serving import Engine, SamplingParams

    def once():
        engine = Engine(cfg, params, max_slots=max_slots,
                        num_pages=1 + 8 * len(prompts), page_size=8)
        for i, p in enumerate(prompts):
            engine.add_request(p, SamplingParams(max_tokens=max_tokens,
                                                 seed=i))
        return engine.run(), engine

    (out, engine), dt, samples = timed(once, reps=reps, warmup=warmup)
    toks = sum(len(v) for v in out.values())
    return out, toks, dt, samples, engine


def run(smoke: bool = False) -> bool:
    cfg = get_smoke_config(ARCH)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    gen = 8
    ok = True

    # ---- parity gate: greedy engine == dense reference ------------------
    from repro.launch.serve import generate, generate_dense
    prompts_same = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 8)),
        jnp.int32)
    dense = np.asarray(generate_dense(cfg, params, prompts_same, gen))
    eng = np.asarray(generate(cfg, params, prompts_same, gen))
    parity = bool(np.array_equal(dense, eng))
    ok &= parity

    # mixed-length continuous batching vs per-request dense; the timed
    # reps (post-compile) double as the smoke throughput metric
    mixed = _requests(cfg, 3, seed=1)
    out, toks, _, samples, engine = _engine_run(
        cfg, params, mixed, max_slots=2, max_tokens=gen, reps=2, warmup=1)
    record_timed("serving/smoke/tok_per_s", samples, unit="tok/s",
                 higher_is_better=True, transform=lambda s: toks / s)
    mixed_parity = True
    for rid, p in zip(sorted(out), mixed):
        ref = np.asarray(generate_dense(
            cfg, params, jnp.asarray(p, jnp.int32)[None], gen))[0]
        mixed_parity &= bool(np.array_equal(ref, np.asarray(out[rid])))
    ok &= mixed_parity

    # interpret-mode paged kernel vs the engine's gather fallback
    from repro import tcec_paged_attention
    rng = np.random.default_rng(2)
    kp = jnp.asarray(rng.standard_normal((9, 8, 2, 64)), jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((9, 8, 2, 64)), jnp.bfloat16)
    q = jnp.asarray(rng.standard_normal((2, 8, 64)), jnp.float32)
    bt = jnp.asarray(np.arange(1, 9).reshape(2, 4), jnp.int32)
    lens = jnp.asarray([13, 27], jnp.int32)
    kout = tcec_paged_attention(q, kp, vp, bt, lens, pages_per_step=2,
                                interpret=True)
    from repro.models.layers import _decode_attend
    class _C:
        attn_softcap = None
    kg = kp[bt].reshape(2, 32, 2, 64)
    vg = vp[bt].reshape(2, 32, 2, 64)
    fb = _decode_attend(q[:, None], kg, vg, _C(), lens - 1, 0)[:, 0]
    kerr = float(jnp.max(jnp.abs(kout - fb)))
    kernel_ok = kerr < 5e-2
    ok &= kernel_ok

    record("serving/parity/dense", float(parity))
    record("serving/parity/mixed", float(mixed_parity))
    # deterministic in-process, but XLA-CPU reductions vary a little
    # across machines: 50% self-noise keeps the gate on >2.5x blowups
    record("serving/kernel/max_abs_err", kerr, unit="abs",
           higher_is_better=False, noise=0.5 * kerr)
    record("serving/mixed/prefills", engine.n_prefills, unit="count",
           higher_is_better=False)
    record("serving/mixed/decode_steps", engine.n_decode_steps,
           unit="count", higher_is_better=False)
    # resilience counters: all zero on a fault-free run, so a change that
    # starts tripping recovery paths in normal operation moves a gated
    # metric (docs/robustness.md)
    stats = engine.stats()
    for key in ("guard_trips", "fallback_reruns", "numerics_errors",
                "rejections", "overloads", "timeouts", "length_caps",
                "prefill_faults", "preemptions", "parks"):
        record(f"serving/resilience/{key}", float(stats[key]),
               unit="count", higher_is_better=False)
    for key in ("failures", "declined"):
        record(f"serving/resilience/breaker_{key}",
               float(stats["breaker"][key]), unit="count",
               higher_is_better=False)
    rows = [["greedy engine == dense generate (4x8+8)", str(parity)],
            ["mixed-length engine == per-request dense", str(mixed_parity)],
            [f"paged kernel vs gather fallback (max|d|={kerr:.1e})",
             str(kernel_ok)]]
    emit("serving_parity",
         "Serving parity gate — paged continuous batching vs dense legacy",
         ["check", "pass"], rows,
         f"{engine.n_prefills} prefills / {engine.n_decode_steps} decode "
         "steps for the mixed run (continuous batching, 3 requests on 2 "
         "slots)")

    # ---- request latency via tracing (repro.obs) ------------------------
    # One traced engine run feeds the serving/latency/* histograms; the
    # percentiles become gated wall-clock metrics (kind="measured", so the
    # twice-run determinism battery exempts them from bit-identity).  The
    # histograms are reset first: that battery runs this bench twice
    # in-process and the percentiles should describe THIS run.
    from repro import obs
    for name in ("ttft_s", "tpot_s", "queue_wait_s"):
        obs.metrics.histogram(f"serving/latency/{name}").reset()
    with obs.trace():
        _engine_run(cfg, params, mixed, max_slots=2, max_tokens=gen)
    ttft = obs.metrics.histogram("serving/latency/ttft_s")
    tpot = obs.metrics.histogram("serving/latency/tpot_s")
    qwait = obs.metrics.histogram("serving/latency/queue_wait_s")
    lat_ok = ttft.count() == len(mixed) and tpot.count() > 0
    ok &= lat_ok
    for label, hist, p in (("ttft_p50_s", ttft, 50), ("ttft_p99_s", ttft, 99),
                           ("tpot_p50_s", tpot, 50), ("tpot_p99_s", tpot, 99),
                           ("queue_wait_p50_s", qwait, 50)):
        record(f"serving/latency/{label}", hist.percentile(p), unit="s",
               kind="measured", higher_is_better=False)

    # ---- shared-prefix scenario: COW prefix cache on vs off -------------
    # Deterministic trace, mixed lengths, ~70% shared system prompt (the
    # docs/serving.md workload).  A primer request populates the prefix
    # tree, then a burst of 8 requests lands at once: cache-off recomputes
    # the 192 shared tokens per request in its batched monolithic
    # prefills, cache-on prefills only each novel tail against read-only
    # shared pages.  The cache-on run must be token-identical (f32
    # pools), score a nonzero hit-rate, and beat the cache-off TTFT p50.
    from repro import numerics
    from repro.serving import Engine, SamplingParams
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab_size, 192)      # shared system prompt
    tails = [rng.integers(0, cfg.vocab_size, int(n))
             for n in rng.choice([64, 96], 8)]         # mixed novel tails
    trace_prompts = [np.concatenate([system, t]) for t in tails]
    shared_frac = len(system) * len(trace_prompts) / sum(
        len(p) for p in trace_prompts)
    pgen = 4

    import time as _time

    def _prefix_run(on):
        # exact per-request TTFT (first burst token after the burst's
        # enqueue) via a manual step loop — the obs histogram's fixed
        # buckets are too coarse to resolve the prefill a hit skips.
        # One engine per mode: each Engine owns fresh jit wrappers, so
        # the first two bursts pay every compile and the third measures
        # warm steady-state (repeat traffic — hits land at plen-1 and
        # COW-split the recomputed last page).
        nc = numerics.active().replace(prefix_cache=on)
        eng = Engine(cfg, params, max_slots=len(trace_prompts),
                     num_pages=353, page_size=8, max_pages_per_slot=40,
                     numerics_config=nc, cache_dtype=jnp.float32)
        eng.add_request(system, SamplingParams(max_tokens=2, seed=99))
        eng.run()                         # primer: inserts the system pages

        def burst():
            rids = [eng.add_request(p, SamplingParams(max_tokens=pgen,
                                                      seed=i))
                    for i, p in enumerate(trace_prompts)]
            t0 = _time.perf_counter()
            first: dict[int, float] = {}
            while eng.sched.has_work or eng._inflight is not None:
                eng.step()
                now = _time.perf_counter()
                for rid in rids:
                    req = eng._requests[rid]
                    if rid not in first and (req.out or req.finished):
                        first[rid] = now - t0
            dt = _time.perf_counter() - t0
            out = eng.results()
            return out, sorted(first.values()), \
                sum(len(out[r]) for r in rids) / dt

        burst(), burst()                               # compile warmup
        out, ttfts, tps = burst()
        return (out, eng, float(np.percentile(ttfts, 50)),
                float(np.percentile(ttfts, 99)), tps)

    out_off, _, p50_off, p99_off, _ = _prefix_run(False)
    out_on, eng_on, p50_on, p99_on, tps_on = _prefix_run(True)
    prefix_parity = all(list(out_off[r]) == list(out_on[r])
                        for r in sorted(out_off))
    pstats = eng_on.stats()
    n_reqs = 1 + 3 * len(trace_prompts)   # primer + three bursts
    hit_rate = pstats["prefix_hits"] / n_reqs
    prefix_ok = prefix_parity and pstats["prefix_hits"] > 0
    ok &= prefix_ok
    record("serving/prefix/parity", float(prefix_parity))
    record("serving/prefix/hit_rate", hit_rate, unit="frac",
           higher_is_better=True)
    record("serving/prefix/tokens_reused",
           float(pstats["prefix_tokens_reused"]), unit="tok",
           higher_is_better=True)
    record("serving/prefix/cow_splits", float(pstats["cow_splits"]),
           unit="count", higher_is_better=False)
    record("serving/prefix/tok_per_s", tps_on, unit="tok/s",
           kind="measured", higher_is_better=True)
    for label, val in (("ttft_p50_s", p50_on), ("ttft_p99_s", p99_on),
                       ("ttft_p50_off_s", p50_off),
                       ("ttft_p99_off_s", p99_off)):
        record(f"serving/prefix/{label}", val, unit="s", kind="measured",
               higher_is_better=False)
    record("serving/prefix/ttft_p50_speedup",
           p50_off / p50_on if p50_on else 1.0, unit="x", kind="measured",
           higher_is_better=True)
    emit("serving_prefix",
         "Shared-prefix serving — COW prefix cache on a deterministic "
         f"trace ({shared_frac:.0%} shared system prompt, mixed lengths)",
         ["metric", "value"],
         [["token parity (cache on == off, f32 pools)", str(prefix_parity)],
          ["prefix hit-rate", f"{hit_rate:.2f}"],
          ["prompt tokens reused", pstats["prefix_tokens_reused"]],
          ["COW splits", pstats["cow_splits"]],
          ["TTFT p50 on/off", f"{p50_on:.3f}s / {p50_off:.3f}s"],
          ["TTFT p50 speedup", f"{p50_off / max(p50_on, 1e-9):.2f}x"]],
         "hits map shared pages read-only and prefill only the novel "
         "tail; the last prompt position always recomputes (COW)")

    if smoke:
        return ok

    # ---- throughput vs in-flight requests -------------------------------
    n_req = 8
    prompts = _requests(cfg, n_req, seed=3)
    rows = []
    for slots in (1, 2, 4, 8):
        _, toks, dt, samples, engine = _engine_run(
            cfg, params, prompts, max_slots=slots, max_tokens=gen,
            reps=2, warmup=1)
        rows.append([slots, toks, f"{dt:.2f}s", f"{toks/dt:.1f}",
                     engine.n_prefills, engine.n_decode_steps])
        record_timed(f"serving/slots{slots}/tok_per_s", samples,
                     unit="tok/s", higher_is_better=True,
                     transform=lambda s: toks / s)
    # dense baseline: same-length batch (the only thing it can do)
    prompts_dense = jnp.asarray(
        np.stack([p[:4] for p in prompts]), jnp.int32)
    _, dt, samples = timed(
        lambda: generate_dense(cfg, params, prompts_dense, gen),
        reps=2, warmup=1)
    rows.append(["dense-XLA batch", n_req * gen, f"{dt:.2f}s",
                 f"{n_req*gen/dt:.1f}", 1, gen])
    record_timed("serving/dense_batch/tok_per_s", samples, unit="tok/s",
                 higher_is_better=True,
                 transform=lambda s: n_req * gen / s)
    emit("serving_throughput",
         "Engine tok/s vs in-flight slots (CPU shape run; post-compile, "
         "blocking reps)",
         ["slots", "tokens", "wall", "tok/s", "prefills", "decode steps"],
         rows,
         "decode steps shrink as slots grow: continuous batching advances "
         "every resident request per jitted step")
    return ok


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    smoke = "--smoke" in args
    return 0 if run(smoke=smoke) else 1


if __name__ == "__main__":
    raise SystemExit(main())
