"""Paper Tables 1-2: expected mantissa length kept by the 2-term split,
computed by EXACT enumeration of all 2^23 FP32 mantissas (no sampling), for
RN and RZ and for both fp16 (paper) and bf16 (this framework's MXU input).

Note: exact enumeration reproduces Table 1's 22.75 (RN) and Table 2's ROWS
(which sum to 22.25) — the paper's *text* says 22.5 for RZ, which is
inconsistent with its own Table 2; we record the discrepancy."""
from repro.core.theory import expected_mantissa_length
from .common import emit, record


def run():
    rows = []
    vals = {}
    for fmt_name, mant in [("fp16", 10), ("bf16", 7)]:
        for mode in ["rn", "rz"]:
            e = expected_mantissa_length(mant, mode)
            vals[(fmt_name, mode)] = e
            record(f"table12/{fmt_name}/{mode}/expected_bits", e,
                   unit="bits")
            rows.append([fmt_name, mode.upper(), f"{e:.4f}"])
    ok = (abs(vals[("fp16", "rn")] - 22.75) < 1e-9
          and abs(vals[("fp16", "rz")] - 22.25) < 1e-9
          and vals[("bf16", "rn")] > vals[("bf16", "rz")])
    emit("table12_mantissa",
         "Tables 1-2 — E[mantissa bits kept] by the 2-term split (exact)",
         ["format", "rounding", "E[bits kept] /23"], rows,
         "fp16 RN = 22.75 (matches Table 1); fp16 RZ = 22.25 (matches "
         "Table 2's rows; paper text says 22.5 — text/table discrepancy). "
         f"{'PASS' if ok else 'FAIL'}")
    return ok
