"""Paper Fig. 8: theoretical underflow/gradual-underflow probability of the
residual cast (Eqs. 15/17) vs Monte-Carlo with real IEEE casts, for the
paper's FP16 and this framework's bf16 — plus the scaled variants (Eq. 18)
that eliminate them."""
from repro.core import theory
from .common import emit, record


def run():
    rows = []
    ok = True
    gap = 0.0
    for e_v in [-24, -14, -8, -4, 0, 4]:
        pt = theory.p_underflow_gradual(e_v, theory.FP16)
        pu = theory.p_underflow(e_v, theory.FP16)
        mu, mgu = theory.measure_underflow(e_v, theory.FP16, n=100_000)
        pts = theory.p_underflow_gradual(e_v, theory.FP16, scale_bits=11)
        rows.append([e_v, f"{pt:.4f}", f"{mgu:.4f}", f"{pu:.2e}",
                     f"{mu:.2e}", f"{pts:.4f}"])
        gap = max(gap, abs(pt - mgu))
        ok &= abs(pt - mgu) < 5e-3
    # bf16: no underflow anywhere in the moderate range (tf32-like claim)
    bf_ok = all(theory.p_underflow_gradual(e, theory.BF16, scale_bits=8) == 0
                for e in range(-100, 101, 10))
    record("fig8/theory_vs_mc_max_gap", gap, unit="prob",
           higher_is_better=False)
    record("fig8/bf16_scaled_zero_underflow", float(bf_ok))
    emit("fig8_underflow",
         "Fig.8 — P_u+gu / P_u: theory (Eq.15/17) vs Monte-Carlo (fp16)",
         ["e_v", "P_u+gu theory", "P_u+gu measured", "P_u theory",
          "P_u measured", "P_u+gu scaled 2^11"], rows,
         f"theory==MC: {'PASS' if ok else 'FAIL'}; "
         f"bf16 scaled has zero underflow over e in [-100,100]: "
         f"{'PASS' if bf_ok else 'FAIL'}")
    return ok and bf_ok
