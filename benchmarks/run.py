"""Benchmark runner: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1 fig5  # subset
  PYTHONPATH=src python -m benchmarks.run --snapshot # smoke throughput
                                                     # set -> BENCH_*.json

``--snapshot`` brackets each bench with the recorder in
``benchmarks/common.py`` and writes one ``BENCH_<name>.json`` per bench
(default: at the repo root, where they are committed per PR as the
throughput trajectory ``benchmarks/compare.py`` gates CI on).  The
default snapshot set is the throughput benches (fig14, fig14attn,
blocksweep, serving — all registered in smoke form); name others
explicitly to snapshot them too.  When ``experiments/dryrun/*.json``
records exist, a ``BENCH_roofline.json`` with the roofline fractions
from ``repro.launch.roofline`` is written as well.
"""
import argparse
import collections
import json
import os
import subprocess
import sys
import time

from . import (blocksweep, common, fig1_accuracy, fig4_mantissa,
               fig5_rounding, fig8_underflow, fig9_representation,
               fig11_exponent_range, fig13_patterns, fig14_throughput,
               serving_throughput, table12_mantissa_expectation)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

Bench = collections.namedtuple("Bench", ["label", "runner"])

BENCHES = {
    "table12": Bench("benchmarks.table12_mantissa_expectation",
                     table12_mantissa_expectation.run),
    "fig1": Bench("benchmarks.fig1_accuracy", fig1_accuracy.run),
    "fig4": Bench("benchmarks.fig4_mantissa", fig4_mantissa.run),
    "fig5": Bench("benchmarks.fig5_rounding", fig5_rounding.run),
    "fig8": Bench("benchmarks.fig8_underflow", fig8_underflow.run),
    "fig9": Bench("benchmarks.fig9_representation", fig9_representation.run),
    "fig11": Bench("benchmarks.fig11_exponent_range",
                   fig11_exponent_range.run),
    "fig13": Bench("benchmarks.fig13_patterns", fig13_patterns.run),
    "fig14": Bench("benchmarks.fig14_throughput", fig14_throughput.run),
    "fig14attn": Bench("benchmarks.fig14_throughput:attention",
                       lambda: fig14_throughput.run_attention(smoke=True)),
    "blocksweep": Bench("benchmarks.blocksweep", blocksweep.run),
    "serving": Bench("benchmarks.serving_throughput:smoke",
                     lambda: serving_throughput.run(smoke=True)),
}

# the per-PR throughput trajectory: what --snapshot writes by default
SNAPSHOT_DEFAULT = ["fig11", "fig14", "fig14attn", "blocksweep", "serving"]


def git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, text=True,
            stderr=subprocess.DEVNULL).strip()
    except Exception:
        return "unknown"


def env_fingerprint() -> dict:
    """Where/how this snapshot was measured — compare.py relaxes
    measured-metric gating when the backend differs."""
    import jax
    from repro import numerics
    return {"backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "policy": numerics.active().policy,
            "jax_version": jax.__version__,
            "git_sha": git_sha(),
            "noise_rel": round(common.noise_probe(), 4)}


def write_snapshot(path: str, name: str, ok: bool, env: dict,
                   metrics: dict):
    snap = {"schema": common.SCHEMA_VERSION, "bench": name,
            "ok": bool(ok), "env": env, "metrics": metrics}
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")


def roofline_snapshot(snapshot_dir: str, env: dict,
                      dryrun_dir: str = "experiments/dryrun") -> bool:
    """Write BENCH_roofline.json from dry-run records, if any exist."""
    from repro.launch import roofline
    recs = roofline.load(dryrun_dir) if os.path.isdir(dryrun_dir) else []
    metrics = roofline.snapshot_metrics(recs)
    if not metrics:
        return False
    write_snapshot(os.path.join(snapshot_dir, "BENCH_roofline.json"),
                   "roofline", True, env, metrics)
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("names", nargs="*", metavar="bench",
                    help="benches to run (default: all; under --snapshot: "
                         f"{' '.join(SNAPSHOT_DEFAULT)})")
    ap.add_argument("--snapshot", action="store_true",
                    help="record per-bench BENCH_<name>.json snapshots")
    ap.add_argument("--snapshot-dir", default=REPO_ROOT,
                    help="where snapshots are written (default: repo root)")
    args = ap.parse_args(argv)
    unknown = [n for n in args.names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown bench(es): {', '.join(unknown)} "
                 f"(choose from: {', '.join(BENCHES)})")
    names = args.names or (SNAPSHOT_DEFAULT if args.snapshot
                           else list(BENCHES))
    env = None
    if args.snapshot:
        os.makedirs(args.snapshot_dir, exist_ok=True)
        env = env_fingerprint()
    failures = []
    for name in names:
        t0 = time.time()
        print(f"=== {name} ({BENCHES[name].label}) ===", flush=True)
        if args.snapshot:
            common.begin_snapshot()
            try:
                ok = BENCHES[name].runner()
            finally:
                metrics = common.end_snapshot()
            path = os.path.join(args.snapshot_dir, f"BENCH_{name}.json")
            write_snapshot(path, name, ok, env, metrics)
            print(f"    snapshot: {len(metrics)} metrics -> {path}",
                  flush=True)
        else:
            ok = BENCHES[name].runner()
        print(f"--- {name}: {'PASS' if ok else 'FAIL'} "
              f"({time.time()-t0:.1f}s)\n", flush=True)
        if not ok:
            failures.append(name)
    if args.snapshot and roofline_snapshot(args.snapshot_dir, env):
        print("    snapshot: roofline fractions -> "
              f"{os.path.join(args.snapshot_dir, 'BENCH_roofline.json')}",
              flush=True)
    print(f"== benchmarks: {len(names) - len(failures)}/{len(names)} pass ==")
    if failures:
        print("failed:", ", ".join(failures))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
