"""Benchmark runner: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1 fig5  # subset
"""
import sys
import time
import types

from . import (blocksweep, fig1_accuracy, fig4_mantissa, fig5_rounding,
               fig8_underflow, fig9_representation, fig11_exponent_range,
               fig13_patterns, fig14_throughput, serving_throughput,
               table12_mantissa_expectation)

BENCHES = {
    "table12": table12_mantissa_expectation,
    "fig1": fig1_accuracy,
    "fig4": fig4_mantissa,
    "fig5": fig5_rounding,
    "fig8": fig8_underflow,
    "fig9": fig9_representation,
    "fig11": fig11_exponent_range,
    "fig13": fig13_patterns,
    "fig14": fig14_throughput,
    "fig14attn": types.SimpleNamespace(
        run=lambda: fig14_throughput.run_attention(smoke=True),
        __name__="benchmarks.fig14_throughput:attention"),
    "blocksweep": blocksweep,
    "serving": types.SimpleNamespace(
        run=lambda: serving_throughput.run(smoke=True),
        __name__="benchmarks.serving_throughput:smoke"),
}


def main(argv=None) -> int:
    names = (argv or sys.argv[1:]) or list(BENCHES)
    failures = []
    for name in names:
        t0 = time.time()
        print(f"=== {name} ({BENCHES[name].__name__}) ===", flush=True)
        ok = BENCHES[name].run()
        print(f"--- {name}: {'PASS' if ok else 'FAIL'} "
              f"({time.time()-t0:.1f}s)\n", flush=True)
        if not ok:
            failures.append(name)
    print(f"== benchmarks: {len(names) - len(failures)}/{len(names)} pass ==")
    if failures:
        print("failed:", ", ".join(failures))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
