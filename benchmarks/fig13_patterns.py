"""Paper Fig. 13: accuracy on STARS-H-style real-application exponent
patterns (randtlr / spatial / cauchy) x (urand / exp_rand) inputs."""
import numpy as np
import jax.numpy as jnp

from repro.core import policy_mm
from repro.core.matgen import (cauchy, exp_rand, randtlr, relative_residual,
                               spatial, urand)
from .common import emit, record

METHODS = ["fp32", "tcec_bf16x6", "tcec_bf16x3", "bf16"]


def run():
    n = 256
    bs = {"urand(-1,1)": urand((n, n), seed=3),
          "exp_rand(-15,0)": exp_rand((n, n), -15, 0, seed=4)}
    as_ = {"randtlr": randtlr(n, seed=0), "spatial": spatial(n, seed=1),
           "cauchy": cauchy(n, seed=2)}
    rows = []
    ok = True
    for an, a in as_.items():
        for bn, b in bs.items():
            cells = []
            for m in METHODS:
                c = policy_mm(jnp.asarray(a), jnp.asarray(b), m)
                r = relative_residual(np.asarray(c), a, b)
                record(f"fig13/{an}x{bn.split('(')[0]}/{m}/residual", r,
                       unit="rel", higher_is_better=False)
                cells.append(f"{r:.2e}")
            r32 = float(cells[0].replace("e", "E"))
            r6 = float(cells[1].replace("e", "E"))
            ok &= r6 <= 4 * r32 + 1e-12
            rows.append([f"{an} x {bn}"] + cells)
    emit("fig13_patterns",
         "Fig.13 — real-application exponent patterns (relative residual)",
         ["pattern"] + METHODS, rows,
         f"tcec_bf16x6 == fp32 accuracy on every pattern: "
         f"{'PASS' if ok else 'FAIL'}")
    return ok
