"""Paper Fig. 9: per-value relative representation error of each format /
split scheme over the FP32 exponent range — shows fp16 schemes lose range
(underflow band) while bf16 splits cover the full range at their mantissa
budget."""
import numpy as np

from repro.core.theory import representable_relative_error
from .common import emit, record

SCHEMES = ["fp32", "bf16", "fp16", "tcec_bf16x3", "tcec_bf16x6",
           "fp16_halfhalf", "fp16_markidis"]


def run():
    rng = np.random.default_rng(0)
    rows = []
    ok = True
    for e in [-40, -20, -10, 0, 10, 30]:
        vals = (rng.uniform(1, 2, 4096) * 2.0 ** e).astype(np.float32)
        cells = []
        for s in SCHEMES:
            rel = representable_relative_error(vals, s)
            cells.append(f"{np.max(rel):.1e}")
        rows.append([f"2^{e}"] + cells)
    # invariants: bf16x6 covers all ranges at ~fp32 fidelity
    for e_i, e in enumerate([-40, -20, -10, 0, 10, 30]):
        vals = (rng.uniform(1, 2, 4096) * 2.0 ** e).astype(np.float32)
        r6 = np.max(representable_relative_error(vals, "tcec_bf16x6"))
        record(f"fig9/scale2^{e}/tcec_bf16x6/max_rel_err", float(r6),
               unit="rel", higher_is_better=False)
        ok &= r6 < 2 ** -21
    # fp16 halfhalf degrades below ~2^-14 (paper Fig. 9 left tail)
    tail = (rng.uniform(1, 2, 4096) * 2.0 ** -40).astype(np.float32)
    hh = np.max(representable_relative_error(tail, "fp16_halfhalf"))
    b6 = np.max(representable_relative_error(tail, "tcec_bf16x6"))
    # recorded separately: the ratio is infinite (b6 is exactly 0 there)
    record("fig9/tail2^-40/fp16_halfhalf/max_rel_err", float(hh),
           unit="rel", higher_is_better=False)
    record("fig9/tail2^-40/tcec_bf16x6/max_rel_err", float(b6),
           unit="rel", higher_is_better=False)
    ok &= hh > b6
    emit("fig9_representation",
         "Fig.9 — max relative representation error per value scale",
         ["scale"] + SCHEMES, rows,
         f"bf16x6 full-range at fp32 fidelity; fp16 schemes lose the low "
         f"tail: {'PASS' if ok else 'FAIL'}")
    return ok
