"""Paper §Parameter tuning (Table 3): BlockSpec grid search with the VMEM
capacity filter (the TPU analogue of CUTLASS's shared-memory filter), plus
an interpret-mode correctness gate per surviving candidate (the analogue of
the paper's error-threshold filter).

Part 2 runs the *measured* autotuner (kernels/tuning.py) on the same
problem and reports the tuned block vs the static heuristic, plus the
on-disk cache entry it persisted — the paper's point that the parameter
sweep, not the math, is what turns the corrected GEMM into a win."""
import itertools
import json
import os

import jax.numpy as jnp
import numpy as np

from repro import VMEM_BUDGET, get_policy, tcec_matmul, tuning, vmem_bytes
from repro.core.matgen import relative_residual, urand
from . import common
from .common import emit, record

CAND = [128, 256, 512]


def run():
    pol = "tcec_bf16x6"
    policy = get_policy(pol)
    a = urand((256, 256), seed=0)
    b = urand((256, 256), seed=1)
    rows = []
    n_total, n_vmem_ok, n_acc_ok = 0, 0, 0
    for bm, bn, bk in itertools.product(CAND, CAND, CAND):
        n_total += 1
        vb = vmem_bytes((bm, bn, bk), policy)
        fits = vb <= VMEM_BUDGET
        status = "vmem-reject"
        err = ""
        if fits:
            n_vmem_ok += 1
            if max(bm, bn, bk) <= 256:  # runnable at this problem size
                out = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy=pol,
                                  block=(bm, bn, bk), interpret=True)
                r = relative_residual(np.asarray(out), a, b)
                err = f"{r:.1e}"
                okacc = r < 0.1           # paper's 0.1 threshold
                n_acc_ok += okacc
                status = "ok" if okacc else "acc-reject"
            else:
                status = "ok(unrun)"
                n_acc_ok += 1
        rows.append([f"({bm},{bn},{bk})", f"{vb/2**20:.1f} MiB", status, err])
    emit("blocksweep",
         "Table 3 analogue — BlockSpec sweep with VMEM + accuracy filters",
         ["block", "VMEM", "status", "rel.residual"], rows,
         f"{n_total} candidates -> {n_vmem_ok} fit VMEM -> {n_acc_ok} pass "
         "the 0.1 accuracy threshold (paper's filter pipeline)")
    record("blocksweep/candidates", n_total, unit="count")
    record("blocksweep/vmem_ok", n_vmem_ok, unit="count")
    record("blocksweep/acc_ok", n_acc_ok, unit="count")

    # ---- part 2: measured autotuner vs static heuristic -----------------
    os.makedirs(common.OUT_DIR, exist_ok=True)
    cache = tuning.BlockCache(path=os.path.join(common.OUT_DIR,
                                                "autotune.json"))
    M = N = K = 256
    heur = tuning.heuristic_block(M, N, K, pol)
    tuned, meta = tuning.autotune(
        1, M, N, K, pol, cache=cache, reps=1, max_candidates=8,
        # interpret-mode wall clock: relative ordering only, no TPU here
        measure=lambda blk: tuning._measure_block(
            1, M, N, K, pol, blk, reps=1, interpret=True))
    trows = [[f"{M}x{N}x{K}", pol, f"{heur}", f"{tuned}",
              f"{meta.get('ms', 0):.1f} ms" if meta.get("ms") else "-",
              meta["source"]]]
    # a second lookup must hit the cache (and would cross processes via the
    # JSON file written above)
    _, meta2 = tuning.autotune(1, M, N, K, pol, cache=cache)
    with open(cache.path) as f:
        n_persisted = len(json.load(f)["entries"])
    emit("blocksweep_tuned",
         "Measured autotuner vs static heuristic (kernels/tuning.py)",
         ["problem", "policy", "heuristic block", "tuned block",
          "best time", "source"], trows,
         f"re-lookup source={meta2['source']}; {n_persisted} entr(y/ies) "
         f"persisted to {cache.path}")
    record("blocksweep/cache_roundtrip",
           float(meta2["source"] == "cache"))
    record("blocksweep/persisted_entries", n_persisted, unit="count")
    if meta.get("ms"):
        # interpret-mode wall clock of the winning block: ordering-only
        # signal; 100% self-noise so only a >4x blowup vs baseline gates
        record("blocksweep/tuned_best_ms", meta["ms"], unit="ms",
               kind="measured", higher_is_better=False,
               noise=float(meta["ms"]))
    return n_acc_ok > 0 and meta2["source"] == "cache"
