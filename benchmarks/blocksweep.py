"""Paper §Parameter tuning (Table 3): BlockSpec grid search with the VMEM
capacity filter (the TPU analogue of CUTLASS's shared-memory filter), plus
an interpret-mode correctness gate per surviving candidate (the analogue of
the paper's error-threshold filter)."""
import itertools

import jax.numpy as jnp
import numpy as np

from repro.core.matgen import relative_residual, urand
from repro.core.policy import get_policy
from repro.kernels import VMEM_BUDGET, tcec_matmul, vmem_bytes
from .common import emit

CAND = [128, 256, 512]


def run():
    pol = "tcec_bf16x6"
    policy = get_policy(pol)
    a = urand((256, 256), seed=0)
    b = urand((256, 256), seed=1)
    ref = a.astype(np.float64) @ b.astype(np.float64)
    rows = []
    n_total, n_vmem_ok, n_acc_ok = 0, 0, 0
    for bm, bn, bk in itertools.product(CAND, CAND, CAND):
        n_total += 1
        vb = vmem_bytes((bm, bn, bk), policy)
        fits = vb <= VMEM_BUDGET
        status = "vmem-reject"
        err = ""
        if fits:
            n_vmem_ok += 1
            if max(bm, bn, bk) <= 256:  # runnable at this problem size
                out = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy=pol,
                                  block=(bm, bn, bk), interpret=True)
                r = relative_residual(np.asarray(out), a, b)
                err = f"{r:.1e}"
                okacc = r < 0.1           # paper's 0.1 threshold
                n_acc_ok += okacc
                status = "ok" if okacc else "acc-reject"
            else:
                status = "ok(unrun)"
                n_acc_ok += 1
        rows.append([f"({bm},{bn},{bk})", f"{vb/2**20:.1f} MiB", status, err])
    emit("blocksweep",
         "Table 3 analogue — BlockSpec sweep with VMEM + accuracy filters",
         ["block", "VMEM", "status", "rel.residual"], rows,
         f"{n_total} candidates -> {n_vmem_ok} fit VMEM -> {n_acc_ok} pass "
         "the 0.1 accuracy threshold (paper's filter pipeline)")
    return n_acc_ok > 0
