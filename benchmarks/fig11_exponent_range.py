"""Paper Fig. 11: GEMM accuracy under exponent-range input Types 1-4
(exp_rand combinations). The paper's tf32tf32 holds FP32 accuracy in all
types; halfhalf fails Types 2-4. Our bf16 schemes inherit the tf32
behaviour (8-bit exponent)."""
import numpy as np
import jax.numpy as jnp

from repro.core import policy_mm
from repro.core.matgen import exp_rand, relative_residual
from .common import emit, record

METHODS = ["fp32", "tcec_bf16x6", "fp16_halfhalf"]


def _mats(n, kind, seed):
    if kind == "hi":
        return exp_rand((n, n), -15, 14, seed=seed)
    if kind == "lo":
        return exp_rand((n, n), -35, -15, seed=seed)
    return exp_rand((n, n), -100, -35, seed=seed)


TYPES = {
    "Type1": ("hi", "hi"),
    "Type2": ("hi", "out"),
    "Type3": ("lo", "lo"),
    "Type4": ("out", "out"),
}


def run():
    n = 128
    rows = []
    res = {}
    for ti, (tname, (ka, kb)) in enumerate(TYPES.items()):
        # NB not hash(tname): string hashes are salted per process
        # (PYTHONHASHSEED), which made this benchmark's claim check flaky
        a = _mats(n, ka, seed=2 * ti)
        b = _mats(n, kb, seed=2 * ti + 1)
        cells = []
        for m in METHODS:
            c = policy_mm(jnp.asarray(a), jnp.asarray(b), m)
            r = relative_residual(np.asarray(c), a, b)
            res[(tname, m)] = r
            record(f"fig11/{tname}/{m}/residual", r, unit="rel",
                   higher_is_better=False)
            cells.append(f"{r:.2e}")
        rows.append([tname] + cells)
    ok = True
    for t in TYPES:
        ok &= res[(t, "tcec_bf16x6")] <= 4 * res[(t, "fp32")] + 1e-12
    ok &= res[("Type3", "fp16_halfhalf")] > 10 * res[("Type3", "tcec_bf16x6")]
    emit("fig11_exponent_range",
         "Fig.11 — exponent-range Types 1-4 (relative residual)",
         ["type"] + METHODS, rows,
         f"bf16x6 matches fp32 on all types (tf32tf32 behaviour); "
         f"fp16_halfhalf loses Type3: {'PASS' if ok else 'FAIL'}")
    return ok
