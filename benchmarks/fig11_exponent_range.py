"""Paper Fig. 11: GEMM accuracy under exponent-range input Types 1-4
(exp_rand combinations), extended across the whole policy family.  The
paper's tf32tf32 holds FP32 accuracy in all types; halfhalf fails Types
2-4.  Our bf16 schemes inherit the tf32 behaviour (8-bit exponent); the
multi-term ``tcec_bf16x9`` sits strictly below x6 (compensated
accumulation removes the f32 noise floor); the fp8 policies only cover
their own storage band, so they run the per-policy safe-band row of the
accuracy/throughput frontier instead of the paper types.

The METHODS list is the registry-completeness contract: CI greps every
``repro.POLICIES`` name here, and ``run()`` asserts the list matches the
registry, so adding a policy without benchmarking it fails the build.
"""
import numpy as np
import jax.numpy as jnp

from repro import POLICIES
from repro.core import policy_mm, theory
from repro.core.matgen import exp_rand, relative_residual
from .common import emit, record

METHODS = [
    "fp32",
    "bf16",
    "tcec_bf16x3",
    "tcec_bf16x6",
    "tcec_bf16x9",
    "tcec_bf16x10",
    "tcec_fp8e4m3x6",
    "tcec_fp8e4m3x10",
    "tcec_fp8e5m2x6",
    "fp16_markidis",
    "fp16_halfhalf",
]

# paper-type columns: policies whose storage covers the Type bands
# (fp8's narrow exponent cannot represent the Type operands at all —
# they appear in the safe-band frontier rows instead)
TYPE_METHODS = [m for m in METHODS if "fp8" not in m]


def _mats(n, kind, seed):
    if kind == "hi":
        return exp_rand((n, n), -15, 14, seed=seed)
    if kind == "lo":
        return exp_rand((n, n), -35, -15, seed=seed)
    return exp_rand((n, n), -100, -35, seed=seed)


TYPES = {
    "Type1": ("hi", "hi"),
    "Type2": ("hi", "out"),
    "Type3": ("lo", "lo"),
    "Type4": ("out", "out"),
}


def _band(pol):
    """Per-policy operand-exponent band: the theory safe range where
    non-empty, else the storage format's representable band (fp8_e4m3)."""
    if pol.is_plain():
        if pol.name == "fp32":
            return (-30, 14)
        fmt = theory.FORMATS_BY_DTYPE[pol.dtype]
        lo, hi = theory.representable_range(fmt)
    else:
        fmt = theory.FORMATS_BY_DTYPE[pol.dtype]
        lo, hi = theory.safe_exponent_range(fmt, pol.scale_bits)
        if lo > hi:
            lo, hi = theory.representable_range(fmt)
    return max(lo, -40), min(hi, 14)


def run():
    assert sorted(METHODS) == sorted(POLICIES), (
        "fig11 METHODS out of sync with repro.POLICIES")
    n = 128
    rows = []
    res = {}
    for ti, (tname, (ka, kb)) in enumerate(TYPES.items()):
        # NB not hash(tname): string hashes are salted per process
        # (PYTHONHASHSEED), which made this benchmark's claim check flaky
        a = _mats(n, ka, seed=2 * ti)
        b = _mats(n, kb, seed=2 * ti + 1)
        cells = []
        for m in TYPE_METHODS:
            c = policy_mm(jnp.asarray(a), jnp.asarray(b), m)
            r = relative_residual(np.asarray(c), a, b)
            res[(tname, m)] = r
            record(f"fig11/{tname}/{m}/residual", r, unit="rel",
                   higher_is_better=False)
            cells.append(f"{r:.2e}")
        rows.append([tname] + cells)
    # per-policy accuracy/throughput frontier: residual inside the
    # policy's own safe band vs the number of low-precision passes
    frontier_ok = True
    for mi, m in enumerate(METHODS):
        pol = POLICIES[m]
        lo, hi = _band(pol)
        a = exp_rand((n, n), lo, hi, seed=400 + 2 * mi)
        b = exp_rand((n, n), lo, hi, seed=401 + 2 * mi)
        c = policy_mm(jnp.asarray(a), jnp.asarray(b), m)
        r = relative_residual(np.asarray(c), a, b)
        res[("SafeBand", m)] = r
        record(f"fig11/safeband/{m}/residual", r, unit="rel",
               higher_is_better=False)
        record(f"fig11/safeband/{m}/passes", pol.passes, unit="passes",
               higher_is_better=False)
        frontier_ok &= r <= theory.policy_error_bound(pol, n, e_lo=lo)
    rows.append(["SafeBand"] + [f"{res[('SafeBand', m)]:.2e}"
                                for m in TYPE_METHODS])
    ok = True
    for t in TYPES:
        ok &= res[(t, "tcec_bf16x6")] <= 4 * res[(t, "fp32")] + 1e-12
        # multi-term: x9's compensated accumulation must sit strictly
        # below x6; x10 matches x6 (both floored by plain f32 accum)
        ok &= res[(t, "tcec_bf16x9")] < 0.5 * res[(t, "tcec_bf16x6")]
        ok &= res[(t, "tcec_bf16x10")] <= 1.1 * res[(t, "tcec_bf16x6")]
    ok &= res[("Type3", "fp16_halfhalf")] > 10 * res[("Type3", "tcec_bf16x6")]
    ok &= frontier_ok
    emit("fig11_exponent_range",
         "Fig.11 — exponent-range Types 1-4 + per-policy safe band "
         "(relative residual)",
         ["type"] + TYPE_METHODS, rows,
         f"bf16x6 matches fp32 on all types (tf32tf32 behaviour); x9 "
         f"strictly below x6; fp16_halfhalf loses Type3; every policy "
         f"within its closed-form bound on its safe band: "
         f"{'PASS' if ok else 'FAIL'}")
    return ok
