"""Paper Fig. 4: Markidis' split (22.75 expected mantissa bits) is LESS
accurate than truncating the FP32 LSB (22.5 bits) — mantissa loss is not
the dominant error source; the RZ accumulator is (see fig5)."""
import numpy as np
import jax.numpy as jnp

from repro.core import policy_mm
from repro.core.matgen import relative_residual, urand
from .common import emit, record


def _truncate_lsb(x: np.ndarray) -> np.ndarray:
    bits = x.view(np.uint32) & np.uint32(0xFFFFFFFE)
    return bits.view(np.float32)


def run():
    rows = []
    ok = True
    for k in [256, 1024, 4096]:
        a = urand((16, k), seed=k)
        b = urand((k, 16), seed=k + 1)
        # fp32 GEMM on LSB-truncated inputs (E[mantissa] = 22.5 bits)
        c_tr = _truncate_lsb(a).astype(np.float64) @ _truncate_lsb(b).astype(np.float64)
        r_tr = relative_residual(c_tr.astype(np.float32), a, b)
        # Markidis split GEMM on an RZ-chaining accumulator (the real method)
        from repro.core.accum import markidis_gemm_sim
        r_mk = relative_residual(markidis_gemm_sim(a, b, "rz"), a, b)
        r_32 = relative_residual(
            np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), "fp32")), a, b)
        rows.append([k, f"{r_32:.2e}", f"{r_tr:.2e}", f"{r_mk:.2e}"])
        for tag, r in [("fp32", r_32), ("truncate_lsb", r_tr),
                       ("markidis_rz", r_mk)]:
            record(f"fig4/k{k}/{tag}/residual", r, unit="rel",
                   higher_is_better=False)
        if k >= 1024:
            ok &= r_mk > r_tr  # the paper's point
    emit("fig4_mantissa",
         "Fig.4 — LSB-truncated SGEMM beats Markidis despite fewer kept bits",
         ["k", "fp32", "truncate-LSB (22.5b)", "markidis-RZ (22.75b)"],
         [list(map(str, r)) for r in rows],
         f"markidis worse than truncation at k>=1024: {'PASS' if ok else 'FAIL'}")
    return ok
