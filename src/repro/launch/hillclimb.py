import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: re-lowers one cell under config variants and
prints the roofline-term deltas (hypothesis -> change -> before -> after).

  PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen2.5-14b:train_4k
"""
import argparse
import json

# (cell) -> list of (variant-name, hypothesis, overrides dict)
PLANS = {
    "qwen2.5-14b:train_4k": [
        ("baseline", "paper-faithful: tcec_bf16x6 on every contraction", {}),
        ("mixed_attn_bf16",
         "scores/PV are activation-activation dots; bf16+f32-accum there "
         "drops 6 passes->1 on ~40% of FLOPs and kills the f32 score "
         "traffic: compute -35%, memory -40%, collective ~0",
         {"attn_policy": "bf16"}),
        ("mixed_attn_x3",
         "middle ground: x3 on attention keeps ~16-bit mantissa on scores "
         "(safer for long-context logits) at half the x6 cost",
         {"attn_policy": "tcec_bf16x3"}),
        ("logits_x3",
         "the 152k-vocab logit GEMM is ~15% of compute at x6; x3 halves it "
         "while logit softmax tolerates 16-bit mantissa",
         {"attn_policy": "bf16", "logits_policy": "tcec_bf16x3"}),
    ],
    "deepseek-v3-671b:train_4k": [
        ("baseline", "paper-faithful x6 + 1D EP + ZeRO-3 FSDP", {}),
        ("ep2d",
         "FSDP all-gathers of expert weights dominate the collective term "
         "(531 AGs/step); sharding 256 experts over model*data = 1 expert "
         "per chip removes those gathers entirely, trading them for "
         "token all-to-alls ~50x smaller",
         {"ep_mode": "2d"}),
        ("mixed_attn",
         "(after ep2d was refuted: GSPMD replicates tokens across the "
         "conflicting data axis) — orthogonal lever: MLA decompress + "
         "score dots to bf16: memory and compute down, FSDP traffic "
         "untouched",
         {"attn_policy": "bf16"}),
        ("mixed_gs512",
         "bigger dispatch groups (gs 512, cf 1.0) cut one-hot dispatch "
         "traffic per token and slot count ~20%",
         {"attn_policy": "bf16", "capacity_factor": 1.0,
          "moe_group_size": 512}),
    ],
    "mamba2-130m:train_4k": [
        ("baseline", "paper-faithful x6, TP over model axis", {}),
        ("dp_over_model",
         "130M params replicate trivially (0.5 GB); using the model axis "
         "as extra DP removes ALL TP collectives and shrinks per-device "
         "activations 16x: memory -16x, collective -> grad-AR only",
         {"dp_over_model": True}),
        ("dp_mixed",
         "SSD chunk dots in bf16 on top: compute -5x (6 passes -> 1) on "
         "the sequence-mixing matmuls",
         {"dp_over_model": True, "attn_policy": "bf16"}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(PLANS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell
    arch, shape = args.cell.split(":")
    os.makedirs(args.out, exist_ok=True)

    results = []
    for name, hypothesis, overrides in PLANS[args.cell]:
        rec = run_cell(arch, shape, args.multi_pod, overrides=overrides)
        rec["variant"] = name
        rec["hypothesis"] = hypothesis
        rec["overrides"] = overrides
        results.append(rec)
        t = rec["roofline"]
        print(f"[{name:16s}] compute={t['compute_s']:8.3f} "
              f"memory={t['memory_s']:8.3f} "
              f"collective={t['collective_s']:8.3f} "
              f"dom={rec['bottleneck']:10s} "
              f"frac={rec['roofline_fraction']:.3f}", flush=True)
    tag = args.cell.replace(":", "__").replace("/", "_")
    with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
        json.dump(results, f, indent=1)
    base = max(results[0]["roofline"].values())
    best = min(max(r["roofline"].values()) for r in results)
    print(f"\nstep-time bound: {base:.3f}s -> {best:.3f}s "
          f"({base/best:.2f}x)")


if __name__ == "__main__":
    main()
