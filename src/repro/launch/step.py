"""jit-able train / prefill / serve steps + sharding assembly for lowering."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import numerics
from repro.configs import SHAPES
from repro.models import get_model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel import ctx
from . import specs as S


def make_train_step(cfg, opt_cfg: adamw.OptConfig, num_microbatches: int = 1):
    model = get_model(cfg)

    def train_step(state, batch):
        def loss_of(p, b):
            return model.loss_fn(p, b)

        if num_microbatches > 1:
            # gradient accumulation: scan over microbatches (leading split)
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((num_microbatches,
                                         x.shape[0] // num_microbatches)
                                        + x.shape[1:]), b)

            def acc_fn(carry, mb):
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                    state["params"], mb)
                return jax.tree.map(jnp.add, carry, g), (l, m)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            grads, (losses, metrics) = jax.lax.scan(
                acc_fn, zero, micro(batch))
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            metrics = jax.tree.map(lambda x: x.mean(), metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(state["params"], batch)
        new_params, new_opt, om = adamw.apply_updates(
            state["params"], grads, state["opt"], opt_cfg)
        metrics.update(om)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.forward_logits(params, batch)

    return prefill_step


def make_serve_step(cfg):
    model = get_model(cfg)

    def serve_step(params, cache, tokens, cache_index):
        return model.decode_step(params, cache, tokens, cache_index)

    return serve_step


def make_sharded_train_step(cfg, opt_cfg: adamw.OptConfig, mesh,
                            num_microbatches: int = 1):
    """jit the train step with state shardings assembled on ``mesh``.

    The runnable sibling of :func:`lower_cell`'s train branch: same spec
    assembly (``parallel/sharding.py`` rules for params, mirrored optimizer
    specs), returned as ``(jitted_step, state_shardings, batch_shardings)``
    so ``train/loop.py --mesh`` runs and checkpoints against real
    NamedShardings.  The step must be *traced* under
    ``ctx.use_mesh(mesh)`` (the loop does this) so kernel dispatch sees
    the mesh and routes through the ``shard_map`` wrapper.
    """
    state_abs = S.abstract_state(cfg, opt_cfg)
    pspec = shd.param_specs(state_abs["params"], mesh, cfg)
    state_spec = {"params": pspec,
                  "opt": _opt_specs(state_abs["opt"], pspec)}
    state_sh = _ns(mesh, state_spec)
    step_fn = make_train_step(cfg, opt_cfg, num_microbatches)
    jitted = jax.jit(step_fn,
                     in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))

    def batch_shardings(batch_abs):
        return _ns(mesh, shd.batch_specs(cfg, mesh, batch_abs))

    return jitted, state_sh, batch_shardings


# ------------------------------------------------------------- lowering

def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg, shape_name: str, mesh, opt_cfg=None,
               numerics_overrides: dict | None = None):
    """Lower one (arch x shape x mesh) cell; returns (lowered, kind).

    ``numerics_overrides`` scopes the lowering under
    ``repro.numerics.use(**overrides)`` — the dispatch decisions baked
    into the lowered artifact are exactly that config's (the dry-run uses
    this to sweep fused-vs-fallback cost models deterministically).
    """
    with numerics.use(**(numerics_overrides or {})):
        return _lower_cell(cfg, shape_name, mesh, opt_cfg)


def _lower_cell(cfg, shape_name, mesh, opt_cfg):
    shape = SHAPES[shape_name]
    opt_cfg = opt_cfg or adamw.OptConfig(
        moment_dtype=("bfloat16" if cfg.shard_mode == "fsdp_tp"
                      else "float32"),
        factored_v=(cfg.shard_mode == "fsdp_tp"))

    if shape.kind == "train":
        state_abs = S.abstract_state(cfg, opt_cfg)
        pspec = shd.param_specs(state_abs["params"], mesh, cfg)
        state_spec = {"params": pspec, "opt": _opt_specs(state_abs["opt"],
                                                         pspec)}
        batch_abs = S.input_specs(cfg, shape)
        bspec = shd.batch_specs(cfg, mesh, batch_abs)
        step_fn = make_train_step(cfg, opt_cfg)
        with ctx.use_mesh(mesh, shd.batch_axes(cfg, mesh)):
            lowered = jax.jit(
                step_fn,
                in_shardings=(_ns(mesh, state_spec), _ns(mesh, bspec)),
                out_shardings=(_ns(mesh, state_spec), None),
                donate_argnums=(0,),
            ).lower(state_abs, batch_abs)
        return lowered, "train"

    if shape.kind == "prefill":
        params_abs = S.abstract_params(cfg)
        pspec = shd.param_specs(params_abs, mesh, cfg)
        batch_abs = S.input_specs(cfg, shape)
        bspec = shd.batch_specs(cfg, mesh, batch_abs)
        out_spec = P(shd.dp_axes(mesh), None, "model")
        with ctx.use_mesh(mesh, shd.batch_axes(cfg, mesh)):
            lowered = jax.jit(
                make_prefill_step(cfg),
                in_shardings=(_ns(mesh, pspec), _ns(mesh, bspec)),
                out_shardings=NamedSharding(mesh, out_spec),
            ).lower(params_abs, batch_abs)
        return lowered, "prefill"

    # decode
    params_abs = S.abstract_params(cfg)
    pspec = shd.param_specs(params_abs, mesh, cfg)
    tokens, index, cache_abs = S.decode_specs(cfg, shape)
    cspec = shd.cache_specs(cfg, mesh, cache_abs, shape.global_batch,
                            shape.seq_len)
    tspec = (P(shd.dp_axes(mesh))
             if shape.global_batch % shd.data_size(mesh) == 0 else P())
    with ctx.use_mesh(mesh, shd.batch_axes(cfg, mesh)):
        lowered = jax.jit(
            make_serve_step(cfg),
            in_shardings=(_ns(mesh, pspec), _ns(mesh, cspec),
                          NamedSharding(mesh, tspec), NamedSharding(mesh, P())),
            out_shardings=(None, _ns(mesh, cspec)),
            donate_argnums=(1,),
        ).lower(params_abs, cache_abs, tokens, index)
    return lowered, "decode"


def _opt_specs(opt_abs, pspec):
    """Optimizer-state specs mirror the param specs; factored-v stats drop
    the corresponding param dim from the spec; step is replicated."""
    def mk_v(p_spec, v_leaf):
        if isinstance(v_leaf, dict):  # factored second moment
            dims = list(p_spec) + [None] * (
                len(v_leaf["row"].shape) + 1 - len(list(p_spec)))
            return {"row": P(*dims[:-1]),
                    "col": P(*(dims[:-2] + dims[-1:]))}
        return p_spec

    return {
        "m": pspec,
        "v": jax.tree.map(mk_v, pspec, opt_abs["v"],
                          is_leaf=lambda x: isinstance(x, P)),
        "step": P(),
    }
