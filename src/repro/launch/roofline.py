"""Roofline report generator: reads experiments/dryrun/*.json, emits the
EXPERIMENTS.md §Roofline table (single-pod) + §Dry-run summary (both
meshes).

  PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

IMPROVE_HINTS = {
    "compute": "drop correction passes where fidelity is not needed "
               "(tcec_mixed: x3/bf16 for attention probs, x6 for weights)",
    "memory": "fuse attention (flash-blocked everywhere) and cast scores "
              "traffic to bf16; shard the residual stream (Megatron-SP)",
    "collective": "overlap TP all-reduces with compute (async collectives); "
                  "bf16 grad/activation reduction; 2D-shard activations",
}


def load(dir_):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    return f"{b/1e6:.1f}M"


def roofline_table(recs, mesh="16x16"):
    rows = []
    for arch in sorted({r["arch"] for r in recs}):
        for shape in SHAPE_ORDER:
            cell = [r for r in recs
                    if r["arch"] == arch and r["shape"] == shape
                    and r["mesh"] == mesh]
            if not cell:
                continue
            r = cell[0]
            if r["status"] == "skip":
                rows.append([arch, shape, "SKIP (full attention @500k)",
                             "", "", "", "", "", ""])
                continue
            if r["status"] != "ok":
                rows.append([arch, shape, "ERROR", "", "", "", "", "",
                             r.get("error", "")[:40]])
                continue
            t = r["roofline"]
            dom = r["bottleneck"]
            rows.append([
                arch, shape,
                f"{t['compute_s']:.3f}", f"{t['memory_s']:.3f}",
                f"{t['collective_s']:.3f}", dom,
                f"{r['roofline_fraction']:.2f}",
                f"{r['useful_flops_ratio']:.3f}",
                IMPROVE_HINTS.get(dom, "")[:58],
            ])
    return rows


def dryrun_table(recs):
    rows = []
    for r in recs:
        if r["status"] == "ok":
            mem = r.get("memory", {})
            args_gb = mem.get("argument_size_in_bytes", 0) / 2**30
            tmp_gb = mem.get("temp_size_in_bytes", 0) / 2**30
            cc = r["collectives"]["counts"]
            csum = ", ".join(f"{k}:{v}" for k, v in sorted(cc.items()) if v)
            rows.append([r["arch"], r["shape"], r["mesh"], r["kind"],
                         f"{r['compile_s']:.0f}s",
                         f"{args_gb:.2f}", f"{tmp_gb:.1f}",
                         fmt_bytes(r["collectives"]["per_device_bytes"]),
                         csum[:60]])
        else:
            rows.append([r["arch"], r["shape"], r["mesh"], r["status"],
                         "", "", "", "", r.get("reason", r.get("error",
                                                               ""))[:60]])
    return rows


def snapshot_metrics(recs):
    """Dry-run roofline fractions as BENCH-snapshot metrics (the schema
    ``benchmarks/compare.py`` gates on): per ok cell, the effective-peak
    fraction of the dominant roofline term and the useful-FLOPs ratio —
    both analytic (derived from partitioned HLO, not wall clock), both
    higher-is-better."""
    out = {}
    for r in recs:
        if r.get("status") != "ok":
            continue
        key = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        out[key + "/fraction"] = {
            "value": float(r["roofline_fraction"]), "unit": "frac",
            "kind": "analytic", "higher_is_better": True, "noise": 0.0}
        out[key + "/useful_flops"] = {
            "value": float(r["useful_flops_ratio"]), "unit": "frac",
            "kind": "analytic", "higher_is_better": True, "noise": 0.0}
    return out


def md_table(headers, rows):
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    recs = load(args.dir)
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skip" for r in recs)
    err = sum(r["status"] == "error" for r in recs)

    parts = [f"# Roofline + dry-run report ({ok} ok / {skip} skip / "
             f"{err} error of {len(recs)} cells)\n"]
    parts.append("## §Roofline — single-pod (16,16), per-step seconds\n")
    parts.append(md_table(
        ["arch", "shape", "compute_s", "memory_s", "collective_s",
         "bottleneck", "roofline-frac", "useful-flops", "what moves it"],
        roofline_table(recs, "16x16")))
    parts.append("\n## §Dry-run — all cells, both meshes\n")
    parts.append(md_table(
        ["arch", "shape", "mesh", "kind", "compile", "args GiB",
         "temp GiB", "coll bytes/dev", "collectives"],
        dryrun_table(recs)))
    txt = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(txt)
    print(txt)


if __name__ == "__main__":
    main()
