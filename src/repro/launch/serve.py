"""Serving CLI + back-compat ``generate`` over the continuous-batching
engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 16 --gen 32 --temperature 0.8 --top-k 40

Two code paths, one contract:

  * :func:`generate` — the legacy batch API ``(B, P) -> (B, gen_len)``,
    now a thin wrapper over :class:`repro.serving.Engine` (paged KV cache,
    single-shot jitted prefill) for the KV-cache families; its greedy
    output is token-identical to :func:`generate_dense` on smoke configs
    (asserted by tests and the serving benchmark's ``--smoke`` gate).
  * :func:`generate_dense` — the dense-cache reference loop, kept as the
    engine's verification oracle and as the fallback for families without
    a paged decode path (SSM/hybrid/enc-dec/VLM).  Its prompt prefill is
    ONE jitted sequence-level forward (``model.prefill``) where the family
    supports it — not P sequential decode steps.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import numerics
from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import get_model


def fill_dense_cache(cache, kv):
    """Place a sequence-level prefill's K/V (leaves (nL, B, P, ...)) into
    a dense cache tree (leaves (nL, B, max_len, ...))."""
    return jax.tree.map(
        lambda c, k: jax.lax.dynamic_update_slice(
            c, k.astype(c.dtype), (0,) * c.ndim),
        cache, kv)


def generate_dense(cfg, params, prompts, gen_len: int, greedy=True, seed=0):
    """Dense-cache reference: batch of same-length prompts, fixed
    ``gen_len``.  Prefill is one jitted forward when the family supports
    it (KV-cache families), else the legacy decode-step loop."""
    model = get_model(cfg)
    B, P = prompts.shape
    max_len = P + gen_len + 1
    cache = model.init_cache(B, max_len)
    step = jax.jit(model.decode_step)

    if model.prefill is not None:
        logits_all, kv = jax.jit(lambda p, t: model.prefill(p, t))(
            params, prompts)
        cache = fill_dense_cache(cache, kv)
        logits = logits_all[:, -1]
    else:
        logits = None
        for i in range(P):
            logits, cache = step(params, cache, prompts[:, i], i)
    out = []
    key = jax.random.PRNGKey(seed)
    for i in range(gen_len):
        if greedy:
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, :cfg.vocab_size])
        out.append(tok)
        logits, cache = step(params, cache, tok.astype(jnp.int32), P + i)
    return jnp.stack(out, axis=1)


def generate(cfg, params, prompts, gen_len: int, greedy=True, seed=0):
    """Back-compat batch API: prompts (B, P) int32 -> (B, gen_len).

    Routes through the continuous-batching engine (paged KV cache,
    single-shot prefill) for the KV-cache families; greedy output stays
    token-identical to :func:`generate_dense`.  Families without a paged
    decode path fall back to the dense loop unchanged."""
    from repro.serving import DEFAULT_PAGE_SIZE, Engine, SamplingParams
    model = get_model(cfg)
    if model.decode_step_paged is None:
        return generate_dense(cfg, params, prompts, gen_len, greedy, seed)
    B, P = prompts.shape
    ps = DEFAULT_PAGE_SIZE
    pages_per_seq = -(-(P + gen_len + 1) // ps)
    engine = Engine(cfg, params, max_slots=B,
                    num_pages=1 + B * pages_per_seq, page_size=ps,
                    max_pages_per_slot=pages_per_seq)
    sps = [SamplingParams(temperature=0.0 if greedy else 1.0,
                          max_tokens=gen_len, seed=seed + i)
           for i in range(B)]
    rids = [engine.add_request(np.asarray(prompts[i]), sps[i])
            for i in range(B)]
    out = engine.run()
    return jnp.asarray(np.stack([out[r] for r in rids]), jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (per-request; engine families only)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--max-slots", type=int, default=0,
                    help="decode batch width (0 = --batch): smaller forces "
                         "queueing, exercising continuous batching")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="bound the waiting queue: requests past it are "
                         "rejected with EngineOverloaded (0 = unbounded)")
    ap.add_argument("--deadline", type=int, default=0,
                    help="per-request deadline in engine steps; expired "
                         "requests finish with reason=timeout (0 = none)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages copy-on-write "
                         "across requests (docs/serving.md)")
    ap.add_argument("--chunked-prefill", type=int, default=0, metavar="C",
                    help="prefill prompts in C-token chunks interleaved "
                         "with decode steps (0 = single-shot)")
    ap.add_argument("--async-sched", action="store_true",
                    help="overlap host scheduling with the in-flight "
                         "decode step (block only at consume)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="make the first N prompt tokens identical across "
                         "the batch (exercises the prefix cache)")
    ap.add_argument("--mesh-model", type=int, default=0, metavar="N",
                    help="install a (devices/N, N) (data, model) host mesh: "
                         "the engine shards its page pools (KV heads on "
                         "the model axis) and the paged decode kernel runs "
                         "per shard via shard_map (0 = no mesh; see "
                         "docs/parallel.md)")
    numerics.add_cli_overrides(ap)
    from repro import obs
    obs.add_cli_flags(ap)
    args = ap.parse_args()

    import contextlib
    mesh_scope = contextlib.nullcontext()
    if args.mesh_model:
        from repro.launch.mesh import make_host_mesh
        from repro.parallel import ctx
        mesh = make_host_mesh(model=args.mesh_model)
        print(f"mesh: {dict(mesh.shape)}", flush=True)
        mesh_scope = ctx.use_mesh(mesh)
    with numerics.cli_context(args), mesh_scope, obs.cli_session(args):
        _main(args)


def _main(args):
    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.policy:
        cfg = cfg.replace(policy=args.policy)
    if cfg.family in ("vlm", "audio"):
        print("note: serving CLI drives the LM/decoder path of this arch")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts_np = rng.integers(0, cfg.vocab_size,
                              (args.batch, args.prompt_len))
    if args.shared_prefix:
        n = min(args.shared_prefix, args.prompt_len)
        prompts_np[:, :n] = prompts_np[0, :n]
    prompts = jnp.asarray(prompts_np, jnp.int32)

    if model.decode_step_paged is None:
        t0 = time.time()
        out = generate(cfg, params, prompts, args.gen,
                       greedy=args.temperature <= 0)
        dt = time.time() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
        print("sample:", np.asarray(out[0][:16]))
        return

    from repro.serving import (DEFAULT_PAGE_SIZE, Engine, EngineOverloaded,
                               SamplingParams)
    ps = DEFAULT_PAGE_SIZE
    pages = -(-(args.prompt_len + args.gen + 1) // ps)
    slots = args.max_slots or args.batch
    nc = numerics.active()
    if args.prefix_cache or args.chunked_prefill or args.async_sched:
        nc = nc.replace(
            prefix_cache=bool(args.prefix_cache) or nc.prefix_cache,
            chunked_prefill=args.chunked_prefill or nc.chunked_prefill,
            async_sched=bool(args.async_sched) or nc.async_sched)
    engine = Engine(cfg, params, max_slots=slots,
                    num_pages=1 + max(slots, args.batch) * pages,
                    page_size=ps, max_pages_per_slot=pages,
                    max_waiting=args.max_waiting or None,
                    numerics_config=nc)
    t0 = time.time()
    rids = []
    for i in range(args.batch):
        try:
            rids.append(engine.add_request(
                np.asarray(prompts[i]),
                SamplingParams(temperature=args.temperature,
                               top_k=args.top_k, top_p=args.top_p,
                               max_tokens=args.gen, seed=i),
                deadline=args.deadline or None))
        except EngineOverloaded:
            print(f"request {i}: rejected (overloaded — queue at "
                  f"{args.max_waiting})")
    out = engine.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in out.values())
    reasons: dict[str, int] = {}
    for v in out.values():
        reasons[v.finish_reason or "?"] = reasons.get(v.finish_reason
                                                      or "?", 0) + 1
    print(f"engine: {args.batch} requests, {slots} slots, "
          f"{engine.n_prefills} prefills, {engine.n_decode_steps} decode "
          f"steps -> {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print(f"finish reasons: {reasons}")
    stats = engine.stats()
    resilience = {k: stats[k] for k in
                  ("guard_trips", "fallback_reruns", "rejections",
                   "overloads", "timeouts", "preemptions", "parks")}
    print(f"resilience: {resilience}")
    prefix = {k: stats[k] for k in
              ("prefix_hits", "prefix_tokens_reused", "cow_splits",
               "prefix_evictions", "prefill_chunks")}
    print(f"prefix: {prefix}")
    if rids:
        print("sample:", out[rids[0]][:16])


if __name__ == "__main__":
    main()
