"""Batched serving CLI: prefill a batch of prompts, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import get_model


def generate(cfg, params, prompts, gen_len: int, greedy=True, seed=0):
    """prompts: (B, P) int32. Prefill via decode-steps (single code path),
    then autoregressive decode. Returns (B, gen_len)."""
    model = get_model(cfg)
    B, P = prompts.shape
    max_len = P + gen_len + 1
    cache = model.init_cache(B, max_len)
    step = jax.jit(model.decode_step)

    tok = prompts[:, 0]
    logits = None
    for i in range(P):
        logits, cache = step(params, cache, prompts[:, i], i)
    out = []
    key = jax.random.PRNGKey(seed)
    for i in range(gen_len):
        if greedy:
            tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, :cfg.vocab_size])
        out.append(tok)
        logits, cache = step(params, cache, tok.astype(jnp.int32), P + i)
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.policy:
        cfg = cfg.replace(policy=args.policy)
    if cfg.family in ("vlm", "audio"):
        print("note: serving CLI drives the LM/decoder path of this arch")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    out = generate(cfg, params, prompts, args.gen)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0][:16]))


if __name__ == "__main__":
    main()
