import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The XLA_FLAGS write above MUST run before jax initializes a backend: jax
# locks the device count on first init.  REPRO_DRYRUN_DEVICES (typed read
# through the env registry — repro.numerics imports no jax at module
# scope) lets tests use a small world.
from repro.numerics import env_value as _env_value
_n = _env_value("REPRO_DRYRUN_DEVICES")
if _n:
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell,
record memory / FLOPs / collective-traffic evidence for EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]

Without --arch, sweeps all 40 (arch x shape) cells on both meshes.
"""
import argparse
import json
import re
import time
import traceback

import numpy as np


HW = {  # TPU v5e target (assignment constants)
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(type_str: str, dims_str: str) -> int:
    n = 1
    if dims_str:
        for d in dims_str.split(","):
            n *= int(d)
    return n * _BYTES.get(type_str, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective in partitioned HLO.

    Instruction results carry their shapes inline; operand shapes are
    resolved through a name->bytes table built from defining instructions.
    all-reduce traffic is doubled (ring = reduce-scatter + all-gather).
    """
    defs: dict[str, int] = {}
    per_op: dict[str, float] = {op: 0.0 for op in _COLL_OPS}
    count: dict[str, int] = {op: 0 for op in _COLL_OPS}
    inst_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
    for line in hlo_text.splitlines():
        m = inst_re.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        shapes = _SHAPE_RE.findall(rhs.split(" ", 2)[0] if rhs else "")
        # result may be a tuple: sum all member shapes
        res_region = rhs.split(")")[0] if rhs.startswith("(") else \
            rhs.split(" ")[0]
        shapes = _SHAPE_RE.findall(res_region)
        total = sum(_shape_bytes(t, d) for t, d in shapes)
        defs[name] = total
        for op in _COLL_OPS:
            if re.search(rf"\b{op}(\.\d+)?\(", rhs) or \
               rhs.lstrip("(").startswith(op):
                opnds = re.findall(r"%([\w.\-]+)", rhs)
                ob = sum(defs.get(o, 0) for o in opnds)
                if ob == 0:
                    ob = total
                factor = 2.0 if op == "all-reduce" else 1.0
                per_op[op] += factor * ob
                count[op] += 1
                break
    total = sum(per_op.values())
    return {"per_op_bytes": per_op, "counts": count,
            "per_device_bytes": total}


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE) useful-FLOPs yardstick."""
    from repro.launch.specs import abstract_params
    import jax
    params = abstract_params(cfg)

    def leaf_count(tree):
        return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(
            jax.tree.map(lambda x: x, tree)))

    n_total = leaf_count(params)
    n_active = n_total
    if cfg.n_experts:
        # replace full expert count by activated experts
        import jax.tree_util as jtu
        expert, shared = 0, 0
        for path, leaf in jtu.tree_flatten_with_path(params)[0]:
            p = "/".join(str(getattr(k, "key", k)) for k in path)
            if re.search(r"moe/w_(gate|up|down)", p):
                expert += int(np.prod(leaf.shape))
        active = expert * cfg.moe_top_k / cfg.n_experts
        n_active = n_total - expert + active
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             mesh_override=None, overrides: dict | None = None) -> dict:
    # keep native bf16 dots in the lowered HLO: the analyzer must see the
    # TPU target's true operand bytes (see repro.core.policy's
    # _cpu_upcast_dots); scoped via the numerics context instead of a
    # process-wide env write
    from repro import numerics
    with numerics.use(keep_bf16_dots=True):
        return _run_cell(arch, shape_name, multi_pod, mesh_override,
                         overrides)


def _run_cell(arch: str, shape_name: str, multi_pod: bool,
              mesh_override=None, overrides: dict | None = None) -> dict:
    import jax
    from repro.configs import LONG_CONTEXT_ARCHS, SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.step import lower_cell

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "status": "ok"}
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        rec["status"] = "skip"
        rec["reason"] = ("full-attention arch: 500k decode cell skipped per "
                        "assignment; see DESIGN.md §Arch-applicability")
        return rec
    mesh = mesh_override or make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered, kind = lower_cell(cfg, shape_name, mesh)
    rec["kind"] = kind
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    # XLA's own cost_analysis counts while bodies ONCE (scan undercount);
    # keep it for reference but derive the roofline from the trip-count-
    # aware HLO analyzer (repro.launch.hlo_cost).
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    rec["xla_cost_flops_raw"] = float(cost.get("flops", 0.0))
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)}

    from repro.launch.hlo_cost import analyze_hlo
    hc = analyze_hlo(compiled.as_text())
    rec["hlo_flops_per_device"] = hc["dot_flops"]
    rec["hlo_bytes_per_device"] = hc["bytes"]
    rec["collectives"] = {"per_op_bytes": hc["per_op_bytes"],
                          "counts": hc["counts"],
                          "per_device_bytes": hc["per_device_bytes"],
                          "unknown_trip_counts": hc["unknown_trip_counts"]}
    rec["chips"] = chips
    rec["model_flops"] = model_flops(cfg, shape)

    # roofline terms (seconds) — single-step, whole-job view
    flops_total = rec["hlo_flops_per_device"] * chips
    bytes_total = rec["hlo_bytes_per_device"] * chips
    rec["roofline"] = {
        "compute_s": flops_total / (chips * HW["peak_flops_bf16"]),
        "memory_s": bytes_total / (chips * HW["hbm_bw"]),
        "collective_s": hc["per_device_bytes"] / HW["ici_bw"],
    }
    dom = max(rec["roofline"], key=rec["roofline"].get)
    rec["bottleneck"] = dom.replace("_s", "")
    rec["useful_flops_ratio"] = (rec["model_flops"] / flops_total
                                 if flops_total else 0.0)
    # the paper's yardstick: effective-peak fraction of the dominant term
    step_time = max(rec["roofline"].values())
    rec["roofline_fraction"] = (rec["roofline"]["compute_s"] / step_time
                                if step_time else 0.0)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs import SHAPES, list_archs
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, mp)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"compile={rec['compile_s']}s "
                             f"bottleneck={rec['bottleneck']}")
                print(f"[{status:5s}] {tag} {extra}", flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} documented skips, "
          f"{n_err} errors ==")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
