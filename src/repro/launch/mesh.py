"""Production mesh construction (assignment-mandated shape).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so tests/benches keep their 1-CPU-device world while the
dry-run builds 512 placeholder devices."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# Compiler flags a real TPU launch would set for collective/compute overlap
# (recorded here so launch scripts and docs share one source of truth; they
# are no-ops on the CPU dry-run backend).
TPU_XLA_FLAGS = " ".join([
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
])
