"""Abstract input/state specs for lowering — ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, ShapeConfig
from repro.models import get_model
from repro.optim import adamw


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg, shape: ShapeConfig | str) -> dict:
    """Train/prefill batch stand-ins for one (arch x shape) cell."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        # the frontend stub supplies precomputed patch embeddings; total
        # sequence (patches + text) equals the cell's seq_len
        P = cfg.n_frontend_tokens
        batch["tokens"] = _sds((B, S - P), jnp.int32)
        batch["patches"] = _sds((B, P, cfg.frontend_dim), jnp.float32)
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = _sds((B, S, cfg.frontend_dim), jnp.float32)
    if shape.kind == "prefill":
        batch.pop("labels")
    return batch


def decode_specs(cfg, shape: ShapeConfig | str):
    """(tokens, cache_index) stand-ins + abstract cache for decode cells."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["mem_len"] = max(S // 8, 64)
    cache = jax.eval_shape(lambda: model.init_cache(B, S, **kwargs))
    tokens = _sds((B,), jnp.int32)
    index = _sds((), jnp.int32)
    return tokens, index, cache


def abstract_params(cfg):
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_state(cfg, opt_cfg: adamw.OptConfig):
    params = abstract_params(cfg)
    opt = jax.eval_shape(lambda: adamw.init_state(params, opt_cfg))
    return {"params": params, "opt": opt}
