"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
undercounts scan-over-layers models by ~L x (verified empirically — see
EXPERIMENTS.md §Dry-run methodology). This analyzer walks the post-
partitioning HLO text, memoizes per-computation costs, and multiplies
while bodies by their ``known_trip_count`` backend config, giving
per-device:

  * ``dot_flops``        — 2 * prod(result dims) * prod(contracting dims)
  * ``bytes``            — operand + result bytes of top-level instructions
                           (fusion internals excluded: they stay on-chip)
  * ``collective_bytes`` — per-op operand traffic, all-reduce doubled
                           (ring = reduce-scatter + all-gather)

Collectives inside scan bodies are likewise multiplied by trip count —
the earlier flat parse undercounted those too.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
          "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
          "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_OPS = ("all-reduce-start", "all-gather-start", "all-reduce",
             "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute-start", "collective-permute")
_FREE_OPS = ("get-tuple-element", "tuple", "parameter", "constant",
             "bitcast", "after-all", "partition-id", "replica-id")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for t, dims in _SHAPE_RE.findall(text):
        if t not in _BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[t]
    return total


def _result_region(rhs: str) -> str:
    """The result-type prefix of an instruction RHS (handles tuples)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, c in enumerate(rhs):
            depth += c == "("
            depth -= c == ")"
            if depth == 0:
                return rhs[:i + 1]
    return rhs.split(" ")[0]


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    unknown_trip: int = 0
    top_coll: list = field(default_factory=list)   # (desc, bytes)
    top_dots: list = field(default_factory=list)   # (desc, flops)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        self.unknown_trip += other.unknown_trip
        self.top_coll = sorted(
            self.top_coll + [(d, v * mult) for d, v in other.top_coll],
            key=lambda t: -t[1])[:24]
        self.top_dots = sorted(
            self.top_dots + [(d, v * mult) for d, v in other.top_dots],
            key=lambda t: -t[1])[:24]


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[tuple[str, str, str]]] = {}
        self.entry = None
        self._parse(hlo_text)
        self._memo: dict[str, Costs] = {}

    def _parse(self, text: str):
        cur = None
        header_re = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
        inst_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
        for line in text.splitlines():
            s = line.rstrip()
            if not s:
                continue
            hm = header_re.match(s.strip())
            if hm and s.rstrip().endswith("{"):
                cur = hm.group(2)
                self.comps[cur] = []
                if hm.group(1):
                    self.entry = cur
                continue
            if s.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            im = inst_re.match(s)
            if im:
                name, rhs = im.groups()
                self.comps[cur].append((name, _result_region(rhs), rhs))

    # ---------------------------------------------------------------- cost

    def analyze(self) -> Costs:
        assert self.entry, "no ENTRY computation found"
        return self._cost(self.entry)

    def _cost(self, comp: str) -> Costs:
        if comp in self._memo:
            return self._memo[comp]
        total = Costs()
        shapes: dict[str, int] = {}
        raw_shapes: dict[str, str] = {}
        for name, res, rhs in self.comps.get(comp, []):
            shapes[name] = _shape_list_bytes(res)
            raw_shapes[name] = res
            op = self._opname(rhs, res)
            if op == "while":
                body, cond, trip, known = self._while_parts(rhs)
                sub = Costs()
                if body in self.comps:
                    sub.add(self._cost(body))
                if cond in self.comps:
                    sub.add(self._cost(cond))
                if not known:
                    sub.unknown_trip += 1
                total.add(sub, mult=trip)
                continue
            if op in ("fusion", "call", "async-start"):
                called = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", rhs)
                if called and called.group(1) in self.comps:
                    sub = self._cost(called.group(1))
                    nobytes = Costs(flops=sub.flops, coll=dict(sub.coll),
                                    coll_counts=dict(sub.coll_counts),
                                    unknown_trip=sub.unknown_trip)
                    total.add(nobytes)  # fusion internals stay on-chip
                total.bytes += shapes[name] + self._operand_bytes(rhs, shapes)
                continue
            if op in ("dynamic-slice", "gather"):
                total.bytes += 2 * shapes[name]   # read slice + write result
                continue
            if op in ("dynamic-update-slice", "scatter"):
                opnds = re.findall(r"%([\w.\-]+)", rhs[rhs.find("("):])
                upd = shapes.get(opnds[1], 0) if len(opnds) > 1 else 0
                total.bytes += 3 * upd            # in-place r/m/w of region
                continue
            if op == "conditional":
                branches = re.findall(
                    r"(?:branch_computations|true_computation|"
                    r"false_computation)={?%?([\w.\-,% ]+)}?", rhs)
                names = []
                for b in branches:
                    names += [x.strip().lstrip("%") for x in b.split(",")]
                subs = [self._cost(b) for b in names if b in self.comps]
                if subs:  # worst-case branch
                    total.add(max(subs, key=lambda c: c.flops))
                continue
            coll = self._collective(op)
            if coll:
                ob = self._operand_bytes(rhs, shapes) or shapes[name]
                factor = 2.0 if coll == "all-reduce" else 1.0
                total.coll[coll] = total.coll.get(coll, 0.0) + factor * ob
                total.coll_counts[coll] = total.coll_counts.get(coll, 0) + 1
                total.top_coll.append((f"{coll} {res[:48]}", factor * ob))
                continue
            if op == "dot":
                fl = self._dot_flops(res, rhs, raw_shapes)
                total.flops += fl
                total.bytes += shapes[name] + self._operand_bytes(rhs, shapes)
                lhs = self._dot_lhs(rhs)
                lsh = raw_shapes.get(lhs, "?") if lhs else "?"
                total.top_dots.append((f"{lsh[:40]} . -> {res[:40]}", fl))
                continue
            if op in _FREE_OPS:
                continue
            # generic instruction: result bytes only — models producer->
            # consumer fusion on the TPU target (operands are read through
            # the fused producer, not re-materialized from HBM)
            total.bytes += shapes[name]
        self._memo[comp] = total
        return total

    @staticmethod
    def _opname(rhs: str, res: str) -> str:
        tail = rhs[len(res):].strip()
        m = re.match(r"([\w\-]+)", tail)
        return m.group(1) if m else ""

    @staticmethod
    def _collective(op: str) -> str | None:
        for c in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute"):
            if op.startswith(c) or op.startswith(c + "-start"):
                return c
        return None

    @staticmethod
    def _while_parts(rhs):
        body = re.search(r"body=%?([\w.\-]+)", rhs)
        cond = re.search(r"condition=%?([\w.\-]+)", rhs)
        tm = re.search(r'known_trip_count[^0-9]*"?(\d+)"?', rhs)
        trip = int(tm.group(1)) if tm else 1
        return (body.group(1) if body else "", cond.group(1) if cond else "",
                trip, tm is not None)

    @staticmethod
    def _operand_bytes(rhs: str, shapes: dict[str, int]) -> int:
        paren = rhs.find("(")
        if paren < 0:
            return 0
        args = rhs[paren:].split("),")[0]
        return sum(shapes.get(n, 0)
                   for n in re.findall(r"%([\w.\-]+)", args))

    @staticmethod
    def _dot_lhs(rhs: str) -> str | None:
        """First *operand name* of a dot.  Operands are rendered with a type
        prefix (``dot(f32[16,64]{1,0} %arg, ...)``), so skip to the first
        ``%``-prefixed token rather than matching the word after ``(``."""
        m = re.search(r"dot\([^%)]*%([\w.\-]+)", rhs)
        return m.group(1) if m else None

    def _dot_flops(self, res: str, rhs: str, raw_shapes: dict) -> float:
        out_elems = 1
        m = _SHAPE_RE.search(res)
        if m and m.group(2):
            for d in m.group(2).split(","):
                out_elems *= int(d)
        lhs = self._dot_lhs(rhs)
        cd = re.search(r"lhs_contracting_dims={([0-9,]*)}", rhs)
        k = 1
        if lhs and cd:
            lshape = raw_shapes.get(lhs, "")
            sm = _SHAPE_RE.search(lshape)
            if sm and sm.group(2):
                dims = [int(x) for x in sm.group(2).split(",")]
                for idx in cd.group(1).split(","):
                    if idx:
                        k *= dims[int(idx)]
        return 2.0 * out_elems * k


def analyze_hlo(hlo_text: str) -> dict:
    c = HloAnalyzer(hlo_text).analyze()
    return {
        "dot_flops": c.flops,
        "bytes": c.bytes,
        "per_op_bytes": c.coll,
        "counts": c.coll_counts,
        "per_device_bytes": sum(c.coll.values()),
        "unknown_trip_counts": c.unknown_trip,
        "top_collectives": c.top_coll[:12],
        "top_dots": c.top_dots[:12],
    }
