"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 100 --ckpt-dir /tmp/run1 [--policy tcec_bf16x6]

On a real TPU fleet this binary runs once per host (jax.distributed
initializes from the TPU environment); the CPU path exercises the identical
trainer, checkpoint, and data code at smoke scale."""
from __future__ import annotations

import argparse

from repro import numerics
from repro.configs import get_config, get_smoke_config, list_archs
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--policy", default=None,
                    help="GEMM precision policy override")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=0, metavar="N",
                    help="install a (devices/N, N) (data, model) host mesh "
                         "and run the sharded train step (0 = no mesh); "
                         "kernel dispatch then routes through the "
                         "shard_map wrapper (see docs/parallel.md)")
    numerics.add_cli_overrides(ap)
    from repro import obs
    obs.add_cli_flags(ap)
    args = ap.parse_args()

    with numerics.cli_context(args), obs.cli_session(args):
        _main(args)


def _main(args):
    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.policy:
        cfg = cfg.replace(policy=args.policy)
    opt = adamw.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    data = DataConfig(seed=args.seed, global_batch=args.batch,
                      seq_len=args.seq)
    loop = TrainLoopConfig(total_steps=args.steps,
                           ckpt_every=args.ckpt_every)
    mesh = None
    if args.mesh_model:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.mesh_model)
        print(f"mesh: {dict(mesh.shape)}", flush=True)

    def log(msg):
        print(msg, flush=True)

    state, hist = train(cfg, opt, data, loop, args.ckpt_dir, log=log,
                        mesh=mesh)
    for h in hist[:: max(len(hist) // 20, 1)]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"{h['time_s']*1e3:7.1f} ms")
    print(f"final loss: {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
