"""repro: Ootomo-Yokota error-corrected Tensor-Core GEMM (TCEC) as a
first-class precision policy in a multi-pod JAX training/serving framework."""
