"""repro: Ootomo-Yokota error-corrected Tensor-Core GEMM (TCEC) as a
first-class precision policy in a multi-pod JAX training/serving framework.

The public surface — everything examples, benchmarks, and downstream
callers need without touching ``repro.kernels.*`` or
``repro.core.policy`` directly:

* **Verbs** — :func:`repro.matmul`, :func:`repro.einsum`,
  :func:`repro.attention`: policy-routed, differentiable, dispatched to
  the fused Pallas kernels when eligible.
* **Telemetry** — :mod:`repro.obs`: metrics registry, request tracing
  (``with repro.obs.trace(): ...`` + :func:`repro.obs.export`), dispatch
  explainability (:func:`repro.obs.explain`), and the numerics-health
  monitors (``REPRO_MONITOR``).
* **Config** — :mod:`repro.numerics`: the one context-scoped recipe
  (``with repro.numerics.use(policy="tcec_bf16x6", force=True): ...``)
  unifying policy selection, kernel dispatch, and autotuning, with the
  canonical ``REPRO_*`` env registry.
* **Policies** — :class:`repro.Policy` (the frozen recipe dataclass),
  :data:`repro.POLICIES`, :func:`repro.get_policy`.
* **Explicit kernels** — :func:`repro.tcec_matmul`,
  :func:`repro.tcec_attention`, :func:`repro.tcec_paged_attention` for
  callers that want the fused kernel without the dispatch layer, plus the
  :mod:`repro.tuning` autotuner namespace and its VMEM capacity model
  (:data:`repro.VMEM_BUDGET`, :func:`repro.vmem_bytes`).
"""
from . import numerics
from .numerics import (NumericsConfig, attention, einsum, matmul)

__all__ = [
    "numerics", "NumericsConfig", "matmul", "einsum", "attention",
    "Policy", "POLICIES", "get_policy", "pdot", "policy_mm", "policy_bmm",
    "tcec_matmul", "tcec_attention", "tcec_paged_attention", "tuning",
    "shmap", "VMEM_BUDGET", "vmem_bytes", "faults", "guard", "obs",
]

# Heavier subsystems load lazily (PEP 562): `import repro` must stay cheap
# enough for pre-JAX-init users (launch.dryrun reads the env registry
# before the backend locks its device count).
_LAZY = {
    "Policy": ("repro.core.policy", "PrecisionPolicy"),
    "POLICIES": ("repro.core.policy", "POLICIES"),
    "get_policy": ("repro.core.policy", "get_policy"),
    "pdot": ("repro.core.policy", "pdot"),
    "policy_mm": ("repro.core.policy", "policy_mm"),
    "policy_bmm": ("repro.core.policy", "policy_bmm"),
    "tcec_matmul": ("repro.kernels.ops", "tcec_matmul"),
    "tcec_attention": ("repro.kernels.tcec_attention", "tcec_attention"),
    "tcec_paged_attention": ("repro.kernels.tcec_paged_attention",
                             "tcec_paged_attention"),
    "tuning": ("repro.kernels.tuning", None),
    "faults": ("repro.faults", None),
    "guard": ("repro.kernels.guard", None),
    "shmap": ("repro.kernels.shmap", None),
    "obs": ("repro.obs", None),
    "VMEM_BUDGET": ("repro.kernels.tcec_matmul", "VMEM_BUDGET"),
    "vmem_bytes": ("repro.kernels.tcec_matmul", "vmem_bytes"),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    mod = importlib.import_module(mod_name)
    value = mod if attr is None else getattr(mod, attr)
    globals()[name] = value          # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
