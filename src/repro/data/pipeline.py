"""Deterministic synthetic data pipeline.

Generates reproducible token/label batches (and stub modality features) from
a counter-based PRNG, sharded by host: every host materializes only its own
slice of the global batch, which is how a real multi-host input pipeline
feeds ``jax.make_array_from_process_local_data``. Deterministic seeding by
(run_seed, step) makes restarts bit-reproducible — a checkpoint/restart can
replay the exact stream (fault-tolerance requirement)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128


def _rng(seed: int, step: int, host: int):
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, host]))


def host_batch(cfg, data_cfg: DataConfig, step: int,
               host_index: int = 0, num_hosts: int = 1) -> dict:
    """The host-local slice of the global batch at ``step`` (numpy)."""
    assert data_cfg.global_batch % num_hosts == 0
    b = data_cfg.global_batch // num_hosts
    s = data_cfg.seq_len
    rng = _rng(data_cfg.seed, step, host_index)
    # zipf-ish marginals: more realistic logit/softmax magnitudes than uniform
    z = rng.zipf(1.3, size=(b, s + 1))
    tokens_full = np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)
    batch = {"tokens": tokens_full[:, :s],
             "labels": tokens_full[:, 1:s + 1].copy()}
    if cfg.family == "vlm":
        p = cfg.n_frontend_tokens
        s_text = max(s - p, 8)
        batch["tokens"] = tokens_full[:, :s_text]
        batch["patches"] = rng.standard_normal(
            (b, p, cfg.frontend_dim)).astype(np.float32)
        labels = np.full((b, p + s_text), -1, np.int32)
        labels[:, p:] = tokens_full[:, 1:s_text + 1]
        batch["labels"] = labels
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (b, s, cfg.frontend_dim)).astype(np.float32)
    return batch


def device_batch(cfg, data_cfg: DataConfig, step: int, shardings=None):
    """Global batch as (optionally sharded) jax arrays — single-host path."""
    np_batch = host_batch(cfg, data_cfg, step)
    if shardings is None:
        return jax.tree.map(jnp.asarray, np_batch)
    return {k: jax.device_put(v, shardings[k]) for k, v in np_batch.items()}
