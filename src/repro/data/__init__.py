from .pipeline import DataConfig, device_batch, host_batch
