"""Hand-written AdamW with global-norm clipping, warmup-cosine schedule,
configurable moment dtypes, and an optional factored second moment
(Adafactor-style row/col factoring) for 100B+ models where full f32/bf16
Adam state does not fit the per-chip HBM budget (see DESIGN.md §6)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"     # bf16 halves optimizer HBM
    factored_v: bool = False          # Adafactor-style v for >=2D params


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.clip(t, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def init_state(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def mk_m(p):
        return jnp.zeros(p.shape, mdt)

    def mk_v(p):
        if cfg.factored_v and _factorable(p):
            return {"row": jnp.zeros(p.shape[:-1], mdt),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], mdt)}
        return jnp.zeros(p.shape, mdt)

    return {
        "m": jax.tree.map(mk_m, params),
        "v": jax.tree.map(mk_v, params, is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        if isinstance(v, dict):
            g2 = jnp.square(g) + 1e-30
            row = b2 * v["row"].astype(jnp.float32) + (1 - b2) * g2.mean(-1)
            col = b2 * v["col"].astype(jnp.float32) + (1 - b2) * g2.mean(-2)
            v32 = (row[..., None] * col[..., None, :]
                   / jnp.maximum(row.mean(-1)[..., None, None], 1e-30))
            new_v = {"row": row.astype(mdt), "col": col.astype(mdt)}
        else:
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            new_v = v32.astype(mdt)
        mh = m32 / bc1
        vh = v32 / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m32.astype(mdt), new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
