from . import adamw
