"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437]. 61L d_model=7168 128H moe_d_ff=2048 vocab=129280."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, vocab_size=129_280,
    n_heads=128, n_kv_heads=128, head_dim=192,     # qk dim = nope+rope
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
    d_ff=18_432,                                   # first dense layers
    n_experts=256, n_shared_experts=1, moe_top_k=8, moe_d_ff=2048,
    first_dense_layers=3,
    mtp=True,
    rope_theta=10_000.0,
    shard_mode="fsdp_tp",
)

SMOKE = FULL.replace(
    n_layers=3, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=4, head_dim=24,
    q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
    v_head_dim=16,
    d_ff=128, n_experts=4, moe_top_k=2, moe_d_ff=32,
    first_dense_layers=1, moe_group_size=64, shard_mode="tp",
)

register(FULL, SMOKE)
