"""Architecture registry: one module per assigned arch (--arch <id>)."""
from .base import (LONG_CONTEXT_ARCHS, SHAPES, ModelConfig, ShapeConfig,
                   get_config, get_smoke_config, list_archs, register)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "LONG_CONTEXT_ARCHS",
           "get_config", "get_smoke_config", "list_archs", "register"]
