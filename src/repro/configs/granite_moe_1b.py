"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].
24L d_model=1024 16H (GQA kv=8) moe_d_ff=512 vocab=49155."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, vocab_size=49_155,
    n_heads=16, n_kv_heads=8, head_dim=64,
    n_experts=32, moe_top_k=8, moe_d_ff=512,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, vocab_size=128,
    n_heads=4, n_kv_heads=2, head_dim=16,
    n_experts=4, moe_top_k=2, moe_d_ff=32, moe_group_size=64,
)

register(FULL, SMOKE)
