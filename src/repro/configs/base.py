"""Model / run configuration schema and the --arch registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab_size: int
    # attention ------------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    sliding_window: int | None = None   # window for local layers
    local_global_period: int = 0        # gemma2: 2 => alternate local/global
    sandwich_norms: bool = False        # gemma2 pre+post norms
    scale_embeddings: bool = False      # gemma: x *= sqrt(d_model)
    rope_theta: float = 10_000.0
    # mlp -------------------------------------------------------------------
    d_ff: int = 0
    activation: str = "silu"            # silu (SwiGLU) | gelu (GeGLU)
    # MLA (deepseek) ---------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # MoE ---------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD) -------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # hybrid (zamba2) ------------------------------------------------------------
    attn_every: int = 0                 # shared attn block period
    # enc-dec (seamless) ----------------------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    # modality frontend stubs ------------------------------------------------------
    frontend: str | None = None         # vision_stub | audio_stub
    n_frontend_tokens: int = 0
    frontend_dim: int = 0
    # extras ---------------------------------------------------------------------
    tie_embeddings: bool = False
    mtp: bool = False                   # deepseek multi-token prediction head
    norm_eps: float = 1e-6
    # numerics / perf -------------------------------------------------------------
    policy: str = "tcec_bf16x6"         # GEMM precision policy (the paper knob)
    logits_policy: str | None = None    # override for the logit matmul
    attn_policy: str | None = None      # override for sequence-mixing dots
                                        # (scores/PV/SSD-chunk) — the
                                        # beyond-paper tcec_mixed knob
    remat: bool = True
    shard_mode: str = "tp"              # tp | fsdp_tp
    dp_over_model: bool = False         # small models: replicate params,
                                        # use the model axis as extra DP
    ep_mode: str = "1d"                 # 1d: experts on model | 2d: experts
                                        # on model x data (no FSDP gathers)
    moe_group_size: int = 0             # 0 = auto

    @property
    def mix_policy(self) -> str:
        return self.attn_policy or self.policy

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def n_rep(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 so the logit dim shards on any
        mesh (the standard MaxText/Megatron vocab-padding trick)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def moe_groups(self) -> int:
        if self.moe_group_size:
            return self.moe_group_size
        return min(512, max(64, self.moe_d_ff // 4))


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs whose long_500k cell runs (sub-quadratic sequence mixing); all others
# record a documented SKIP (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = {"mamba2-130m", "zamba2-1.2b"}

_REGISTRY: dict[str, "ModelConfig"] = {}
_SMOKE: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig, smoke: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from . import (deepseek_v3_671b, gemma_2b, gemma2_9b,  # noqa: F401
                   granite_moe_1b, internvl2_2b, mamba2_130m, qwen3_0_6b,
                   qwen2_5_14b, seamless_m4t_large_v2, zamba2_1_2b)
