"""internvl2-2b [vlm] — InternViT (STUB frontend) + InternLM2 backbone
[arXiv:2404.16821]. 24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553.
``input_specs()`` feeds precomputed patch embeddings (dim 1024, 256/img)."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, vocab_size=92_553,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192,
    frontend="vision_stub", frontend_dim=1024, n_frontend_tokens=256,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    frontend_dim=32, n_frontend_tokens=8,
)

register(FULL, SMOKE)
