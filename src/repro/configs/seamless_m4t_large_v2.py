"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].
24L(enc)+24L(dec) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Speech frontend is a STUB: ``input_specs()`` feeds precomputed frame
embeddings (dim 1024); decode shapes use mem_len = seq_len / 8."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, n_enc_layers=24, d_model=1024, vocab_size=256_206,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=8192,
    is_encoder_decoder=True,
    frontend="audio_stub", frontend_dim=1024,
)

SMOKE = FULL.replace(
    n_layers=2, n_enc_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    frontend_dim=32,
)

register(FULL, SMOKE)
