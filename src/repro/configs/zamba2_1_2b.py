"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242]. 38L d_model=2048 32H d_ff=8192 vocab=32000 ssm_state=64."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, vocab_size=32_000,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=256, ssm_groups=1,
    attn_every=6,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=5, d_model=64, vocab_size=128,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    attn_every=2,
)

register(FULL, SMOKE)
