"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-14B].
48L d_model=5120 40H (kv=8) d_ff=13824 vocab=152064."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, vocab_size=152_064,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=13_824,
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
)

register(FULL, SMOKE)
