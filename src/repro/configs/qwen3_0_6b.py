"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-0.6B].
28L d_model=1024 16H (kv=8) d_ff=3072 vocab=151936."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, vocab_size=151_936,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=3072,
    qk_norm=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
)

register(FULL, SMOKE)
