"""gemma2-9b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118]. 42L d_model=3584 16H (kv=8) d_ff=14336 vocab=256000."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, vocab_size=256_000,
    n_heads=16, n_kv_heads=8, head_dim=256, d_ff=14_336,
    activation="gelu",
    sliding_window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0,
    sandwich_norms=True,
    tie_embeddings=True, scale_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    sliding_window=8,
)

register(FULL, SMOKE)
