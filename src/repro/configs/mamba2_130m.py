"""mamba2-130m [ssm] — SSD, attention-free [arXiv:2405.21060].
24L d_model=768 d_ff=0 vocab=50280 ssm_state=128."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, vocab_size=50_280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=256, ssm_groups=1,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, vocab_size=128,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
)

register(FULL, SMOKE)
