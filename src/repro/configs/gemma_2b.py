"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295].
18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000."""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, vocab_size=256_000,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16_384,
    activation="gelu",
    tie_embeddings=True, scale_embeddings=True,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, vocab_size=256,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
)

register(FULL, SMOKE)
