"""repro.numerics — the single public configuration spine.

The paper's result is a *recipe*: split count, scale bits, kept terms,
accumulation order.  Before this module the recipe was smeared across
string policy names, an 11-variable ``REPRO_*`` env namespace,
``DispatchConfig.override()``, and per-call kwargs — with a documented
footgun that config changes silently did not retrigger tracing.  This
module replaces all of that with one frozen, hashable
:class:`NumericsConfig` and one precedence rule:

    call-site kwarg  >  innermost ``with repro.numerics.use(...)``
    context          >  process env defaults (parsed once, on first use,
    through the typed registry below)

Three layers live here:

* **Env registry** (:data:`ENV_VARS`) — the canonical list of every
  ``REPRO_*`` variable: name, type, default, docstring.  All environment
  reads in ``src/`` go through :func:`env_value`; a tier-1 test greps the
  tree and fails on any read outside this module, so the sprawl can never
  regrow.  Parsing is typed and total: empty values mean "unset", garbage
  values warn and fall back to the default (``REPRO_FORCE_PALLAS=0`` is
  off, ``REPRO_PALLAS_MIN_DIM=`` is the default — the old truthy-parse
  asymmetries are gone).

* **Config + context** — :func:`active` returns the innermost
  :func:`use` context on this thread, else the env-default config.
  Contexts nest and are thread-local (a worker thread starts from the env
  defaults, not from another thread's context).

* **Trace correctness** — the active config travels as part of the jit
  cache key: every distinct config is interned to a *config epoch*, and
  :func:`use` installs the epoch in JAX's trace context (via
  ``jax.experimental.xla_metadata``, with a cache-clearing fallback).
  Entering or exiting a context therefore deterministically re-lowers
  previously-jitted shapes instead of silently reusing a stale dispatch
  decision; re-entering a config that was already traced reuses its
  cached lowering.

The public verb layer — :func:`matmul`, :func:`einsum`,
:func:`attention` (re-exported as ``repro.matmul`` etc.) — resolves the
policy and kernel knobs through this config, so callers never import
``repro.kernels.*`` or ``repro.core.policy`` directly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import warnings
from dataclasses import dataclass, replace

__all__ = [
    "ENV_VARS", "EnvVar", "NumericsConfig", "active", "use", "env_value",
    "reload_env_defaults", "describe_env", "env_table", "config_epoch",
    "matmul", "einsum", "attention",
]

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


# --------------------------------------------------------------- registry

@dataclass(frozen=True)
class EnvVar:
    """One registered ``REPRO_*`` environment variable."""
    name: str
    kind: str                  # "bool" | "int" | "str" | "path"
    default: object
    doc: str
    field: str | None = None   # NumericsConfig field it feeds (None = raw)
    invert: bool = False       # bool vars that *unset* their field


def _parse_bool(raw: str | None, default):
    if raw is None:
        return default
    t = raw.strip().lower()
    if t == "":
        return default
    if t in _TRUE:
        return True
    if t in _FALSE:
        return False
    warnings.warn(f"unrecognized boolean value {raw!r}; using default "
                  f"{default!r}", stacklevel=3)
    return default


def _parse_int(raw: str | None, default):
    if raw is None or raw.strip() == "":
        return default
    try:
        return int(raw.strip())
    except ValueError:
        warnings.warn(f"unrecognized integer value {raw!r}; using default "
                      f"{default!r}", stacklevel=3)
        return default


def _parse_str(raw: str | None, default):
    if raw is None or raw.strip() == "":
        return default
    return raw.strip()


_PARSERS = {"bool": _parse_bool, "int": _parse_int, "str": _parse_str,
            "path": _parse_str}

_DEFAULT_TUNE_CACHE = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "tcec_autotune.json")

# The canonical REPRO_* namespace.  Order is the documentation order.
ENV_VARS: dict[str, EnvVar] = {v.name: v for v in [
    EnvVar("REPRO_POLICY", "str", "fp32",
           "Default GEMM precision policy for the repro.matmul / "
           "repro.einsum / repro.attention verbs (call-site kwargs and "
           "model configs still win).", field="policy"),
    EnvVar("REPRO_DISABLE_PALLAS", "bool", False,
           "Escape hatch: route every contraction to the XLA "
           "term-expansion fallback.", field="enabled", invert=True),
    EnvVar("REPRO_FORCE_PALLAS", "bool", False,
           "Dispatch to the fused kernels even off-TPU (interpret mode — "
           "tests, CPU verification).", field="force"),
    EnvVar("REPRO_PALLAS_MIN_DIM", "int", 128,
           "Smallest M/N/K (GEMM) or S/T (attention) worth dispatching: "
           "tiny problems lose more to 128-padding than fusion wins.",
           field="min_dim"),
    EnvVar("REPRO_FUSE_EPILOGUE", "bool", False,
           "Fold bias + activation into the GEMM kernel's scaled epilogue "
           "(models.layers.fused_linear).", field="fuse_epilogue"),
    EnvVar("REPRO_DISABLE_FLASH_ATTN", "bool", False,
           "Granular hatch: keep GEMM dispatch but not the fused "
           "flash-attention kernel.", field="flash_attention", invert=True),
    EnvVar("REPRO_DISABLE_PAGED_ATTN", "bool", False,
           "Granular hatch: keep the rest but not the paged "
           "decode-attention kernel (restores exact dense parity).",
           field="paged_attention", invert=True),
    EnvVar("REPRO_SHARD_MAP", "bool", True,
           "Under an installed GSPMD mesh, wrap kernel dispatch in "
           "shard_map (per-device shards; kernels/shmap.py).  0 declines "
           "every dispatch under a mesh to the XLA fallback.",
           field="shard_map"),
    EnvVar("REPRO_TUNE", "bool", False,
           "Force autotuner measurement even off-TPU.", field="tune"),
    EnvVar("REPRO_TUNE_DISABLE", "bool", False,
           "Never measure; heuristic blocks only (wins over REPRO_TUNE).",
           field="tune"),
    EnvVar("REPRO_TUNE_CACHE", "path", _DEFAULT_TUNE_CACHE,
           "Autotuner cache file path.", field="tune_cache"),
    EnvVar("REPRO_GUARD", "bool", True,
           "Guarded dispatch: a fused-kernel failure falls back to the "
           "XLA term-expansion path and quarantines that (backend, kernel, "
           "shape-bucket) key for a cooldown (kernels/guard.py).  0 lets "
           "kernel errors propagate (debugging).", field="guard"),
    EnvVar("REPRO_PREFIX_CACHE", "bool", False,
           "Serving engine: share full prompt pages across requests via "
           "the copy-on-write prefix cache (serving/prefix_cache.py) — "
           "a cached prefix skips its recompute and only the novel tail "
           "prefills.", field="prefix_cache"),
    EnvVar("REPRO_CHUNKED_PREFILL", "int", 0,
           "Serving engine: prefill prompts in chunks of this many tokens "
           "(rounded up to the page size), interleaved with decode steps "
           "so long prompts stop head-of-line-blocking admissions.  0 = "
           "monolithic single-shot prefill.", field="chunked_prefill"),
    EnvVar("REPRO_ASYNC_SCHED", "bool", False,
           "Serving engine: overlap host scheduling with the in-flight "
           "jitted decode step (dispatch one step ahead; block only at "
           "the consume point).  Token-identical to the synchronous "
           "default.", field="async_sched"),
    EnvVar("REPRO_MONITOR", "bool", False,
           "Numerics-health monitors: sampled per-contraction probes of "
           "the paper's underflow-risk indicators (correction-term "
           "underflow fractions, operand exponent range vs the policy's "
           "safe band), recorded into the repro.obs metrics registry "
           "(obs/numerics_health.py).  Off by default — probes add "
           "side computation per monitored contraction.",
           field="monitor"),
    EnvVar("REPRO_FAULTS", "str", "",
           "Fault-injection plan for chaos testing, e.g. "
           "'pool.alloc@0:1;decode.slow@every=4' (repro.faults; empty = "
           "no injection)."),
    EnvVar("REPRO_KEEP_BF16_DOTS", "bool", False,
           "Keep native bf16 dots in lowered HLO on CPU (compiled-artifact "
           "byte accounting for the dry-run; CPU execution may be "
           "unimplemented for some shapes).", field="keep_bf16_dots"),
    EnvVar("REPRO_DRYRUN_DEVICES", "int", 0,
           "Host-platform device count for launch.dryrun (0 = the 512-chip "
           "production world).  Read before JAX initializes."),
    EnvVar("REPRO_BENCH_OUT", "path", "experiments/bench",
           "Output directory for benchmark JSON artifacts."),
]}


def env_value(name: str, environ=None):
    """Typed read of a registered ``REPRO_*`` variable.

    The single chokepoint for environment access: empty values mean
    "unset", unparseable values warn and fall back to the registered
    default.  Unregistered names are a programming error.
    """
    var = ENV_VARS[name]
    raw = (environ if environ is not None else os.environ).get(name)
    return _PARSERS[var.kind](raw, var.default)


def describe_env() -> list[dict]:
    """Registry rows (name/type/default/doc) for docs and tooling."""
    return [{"name": v.name, "type": v.kind, "default": v.default,
             "doc": v.doc} for v in ENV_VARS.values()]


def env_table() -> str:
    """The registry as a markdown table (the docs' knob tables point here)."""
    rows = ["| variable | type | default | effect |",
            "|----------|------|---------|--------|"]
    for v in ENV_VARS.values():
        default = "" if v.default in ("", 0, False) else f"`{v.default}`"
        rows.append(f"| `{v.name}` | {v.kind} | {default} | {v.doc} |")
    return "\n".join(rows)


# ----------------------------------------------------------------- config

def _tuple_or_none(x, n, name):
    if x is None:
        return None
    t = tuple(int(v) for v in x)
    if len(t) != n:
        raise ValueError(f"{name} must have {n} entries, got {x!r}")
    return t


@dataclass(frozen=True)
class NumericsConfig:
    """The full recipe: policy selection, kernel dispatch, and tuning.

    Frozen and hashable — a value object that can key jit caches.  Field
    defaults are the env-variable defaults; see :data:`ENV_VARS` for the
    variable each field parses from.
    """
    # -- policy selection ---------------------------------------------
    policy: str = "fp32"            # default for the public verbs
    # -- kernel dispatch ----------------------------------------------
    enabled: bool = True            # False = XLA fallback wholesale
    force: bool = False             # dispatch even off-TPU (interpret)
    min_dim: int = 128              # smallest M/N/K (or S/T) to dispatch
    block: tuple | None = None      # (bm, bn, bk) GEMM autotuner override
    interpret: bool | None = None   # None = auto (interpret off-TPU)
    fuse_epilogue: bool = False     # models.layers.fused_linear hook
    flash_attention: bool = True    # fused attention kernel routing
    attn_block: tuple | None = None   # (bq, bk) attention override
    paged_attention: bool = True    # paged decode-attention routing
    paged_block: int | None = None  # pages-per-step override
    shard_map: bool = True          # mesh dispatch via kernels/shmap.py
    guard: bool = True              # circuit-breaker guarded dispatch
    # -- serving ------------------------------------------------------
    prefix_cache: bool = False      # COW prefix sharing (serving engine)
    chunked_prefill: int = 0        # prefill chunk tokens (0 = monolithic)
    async_sched: bool = False       # overlap host sched with device step
    # -- observability ------------------------------------------------
    monitor: bool = False           # numerics-health probes (repro.obs)
    # -- autotuning ---------------------------------------------------
    tune: str = "auto"              # "auto" | "force" | "off"
    tune_cache: str = _DEFAULT_TUNE_CACHE
    # -- numerics environment -----------------------------------------
    keep_bf16_dots: bool = False    # keep bf16 dots in CPU-lowered HLO

    def __post_init__(self):
        object.__setattr__(self, "block",
                           _tuple_or_none(self.block, 3, "block"))
        object.__setattr__(self, "attn_block",
                           _tuple_or_none(self.attn_block, 2, "attn_block"))
        if self.tune not in ("auto", "force", "off"):
            raise ValueError(f"tune must be auto|force|off, got {self.tune!r}")
        # fail at the use()/construction site, not as a bare KeyError at
        # the first verb call much later
        from repro.core.policy import POLICIES
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"known: {sorted(POLICIES)}")

    def replace(self, **overrides) -> "NumericsConfig":
        return replace(self, **_canon_overrides(overrides))

    @staticmethod
    def from_env(environ=None) -> "NumericsConfig":
        """Parse the registry into a config (the process-default recipe)."""
        tune = "auto"
        if env_value("REPRO_TUNE", environ):
            tune = "force"
        if env_value("REPRO_TUNE_DISABLE", environ):
            tune = "off"                       # disable wins over force
        from repro.core.policy import POLICIES
        policy = env_value("REPRO_POLICY", environ)
        if policy not in POLICIES:
            warnings.warn(f"REPRO_POLICY={policy!r} is not a registered "
                          f"policy; using {ENV_VARS['REPRO_POLICY'].default!r}")
            policy = ENV_VARS["REPRO_POLICY"].default
        return NumericsConfig(
            policy=policy,
            enabled=not env_value("REPRO_DISABLE_PALLAS", environ),
            force=env_value("REPRO_FORCE_PALLAS", environ),
            min_dim=env_value("REPRO_PALLAS_MIN_DIM", environ),
            fuse_epilogue=env_value("REPRO_FUSE_EPILOGUE", environ),
            flash_attention=not env_value("REPRO_DISABLE_FLASH_ATTN",
                                          environ),
            paged_attention=not env_value("REPRO_DISABLE_PAGED_ATTN",
                                          environ),
            shard_map=env_value("REPRO_SHARD_MAP", environ),
            guard=env_value("REPRO_GUARD", environ),
            prefix_cache=env_value("REPRO_PREFIX_CACHE", environ),
            chunked_prefill=env_value("REPRO_CHUNKED_PREFILL", environ),
            async_sched=env_value("REPRO_ASYNC_SCHED", environ),
            monitor=env_value("REPRO_MONITOR", environ),
            tune=tune,
            tune_cache=env_value("REPRO_TUNE_CACHE", environ),
            keep_bf16_dots=env_value("REPRO_KEEP_BF16_DOTS", environ),
        )


_CONFIG_FIELDS = frozenset(f.name for f in dataclasses.fields(NumericsConfig))


def _canon_overrides(overrides: dict) -> dict:
    unknown = set(overrides) - _CONFIG_FIELDS
    if unknown:
        raise TypeError(f"unknown numerics option(s): {sorted(unknown)}; "
                        f"valid fields: {sorted(_CONFIG_FIELDS)}")
    out = dict(overrides)
    if "policy" in out and out["policy"] is not None \
            and not isinstance(out["policy"], str):
        out["policy"] = out["policy"].name     # PrecisionPolicy instance
    return out


# -------------------------------------------------- context + env default

_tls = threading.local()
_env_default_lock = threading.Lock()
_ENV_DEFAULT: NumericsConfig | None = None


def _stack() -> list:
    try:
        return _tls.stack
    except AttributeError:
        _tls.stack = []
        return _tls.stack


def _env_default() -> NumericsConfig:
    global _ENV_DEFAULT
    if _ENV_DEFAULT is None:
        with _env_default_lock:
            if _ENV_DEFAULT is None:
                _ENV_DEFAULT = NumericsConfig.from_env()
    return _ENV_DEFAULT


def reload_env_defaults() -> NumericsConfig:
    """Re-parse the env into the process-default config (tests; long-lived
    processes toggling hatches).  If the default actually changed, jit
    caches are cleared — ambient traces would otherwise keep the stale
    recipe (the same staleness :func:`use` solves with config epochs)."""
    global _ENV_DEFAULT
    with _env_default_lock:
        old = _ENV_DEFAULT
        _ENV_DEFAULT = NumericsConfig.from_env()
        changed = old is not None and old != _ENV_DEFAULT
    if changed:
        import jax
        jax.clear_caches()
    return _ENV_DEFAULT


def active() -> NumericsConfig:
    """The innermost context on this thread, else the env defaults."""
    stack = _stack()
    return stack[-1] if stack else _env_default()


# ------------------------------------------------------------ config epoch
#
# Each distinct config is interned to a small integer (its *epoch*).  use()
# installs the epoch in JAX's trace context, so every jit cache downstream
# keys on it: entering a context re-lowers previously-jitted shapes under
# the new recipe, and re-entering an already-seen config hits the cache.

_epoch_lock = threading.Lock()
_EPOCH_IDS: dict[NumericsConfig, int] = {}


def config_epoch(cfg: NumericsConfig | None = None) -> int:
    """The interned epoch id of ``cfg`` (default: the active config).
    Epoch 0 is the env-default config; distinct configs get distinct ids."""
    cfg = cfg if cfg is not None else active()
    if cfg == _env_default():
        return 0
    with _epoch_lock:
        eid = _EPOCH_IDS.get(cfg)
        if eid is None:
            eid = len(_EPOCH_IDS) + 1
            _EPOCH_IDS[cfg] = eid
    return eid


def _epoch_scope(cfg: NumericsConfig):
    """Context manager keying JAX trace caches on ``cfg``'s epoch.

    Uses ``jax.experimental.xla_metadata`` (part of jax's trace context,
    so tracing caches and executable caches both key on it).  When that
    API is unavailable the fallback clears jit caches on entry and exit —
    strictly correct, just not cached across re-entries.

    Epoch 0 (the env-default config) is tagged too: a restore-to-default
    context nested inside a non-default one must *replace* the enclosing
    epoch, or its traces would be keyed (and later cache-hit) under the
    outer config.
    """
    eid = config_epoch(cfg)
    try:
        from jax.experimental.xla_metadata import set_xla_metadata
        return set_xla_metadata(repro_numerics_epoch=str(eid))
    except ImportError:                       # pragma: no cover - old jax
        return _clearing_scope()


@contextlib.contextmanager
def _clearing_scope():                        # pragma: no cover - old jax
    import jax
    jax.clear_caches()
    try:
        yield
    finally:
        jax.clear_caches()


@contextlib.contextmanager
def _scoped(cfg: NumericsConfig):
    """Plain thread-local push, no epoch tag.

    Used for call-site kwargs (the verbs) where the override is a constant
    of the caller's own code: re-traces re-execute the verb body, so the
    jit key needs no extra state."""
    stack = _stack()
    stack.append(cfg)
    try:
        yield cfg
    finally:
        stack.pop()


@contextlib.contextmanager
def use(config: NumericsConfig | None = None, **overrides):
    """Scoped numerics config: ``with repro.numerics.use(policy="tcec_bf16x6",
    force=True): ...``.

    Pass field overrides (applied on the *current* active config — contexts
    nest), or a full :class:`NumericsConfig`, or both (overrides applied on
    the instance).  The context is thread-local and trace-correct: jit
    caches key on the config's epoch, so previously-traced shapes re-lower
    under the new recipe instead of reusing a stale dispatch decision.
    """
    if config is not None:
        if not isinstance(config, NumericsConfig):
            raise TypeError(f"expected NumericsConfig, got {type(config)}")
        cfg = config.replace(**overrides) if overrides else config
    else:
        cfg = active().replace(**overrides)
    with _scoped(cfg), _epoch_scope(cfg):
        yield cfg


def _call_config(overrides: dict) -> NumericsConfig:
    """Call-site kwarg resolution: innermost context + per-call overrides."""
    cfg = active()
    return cfg.replace(**overrides) if overrides else cfg


# ------------------------------------------------------------- verb layer
#
# The public entry points (re-exported as repro.matmul / repro.einsum /
# repro.attention).  Heavy imports are deferred so `import repro` stays
# cheap and this module never participates in an import cycle.

def matmul(a, b, *, policy=None, **overrides):
    """Policy-routed matmul: ``(M, K) @ (K, N)`` or batched ``(B, M, K) @
    (B, K, N)``, f32 accumulation, differentiable (policy-preserving
    backward), dispatched to the fused Pallas kernel when eligible.

    ``policy`` defaults to the active config's (context or ``REPRO_POLICY``
    env default).  Extra kwargs are per-call config overrides — the highest
    precedence level: ``repro.matmul(a, b, policy="tcec_bf16x6",
    force=True, interpret=True)``.
    """
    from repro.core.policy import get_policy, policy_bmm, policy_mm
    cfg = _call_config(overrides)
    pol = get_policy(policy if policy is not None else cfg.policy)
    with _scoped(cfg):
        if getattr(a, "ndim", 2) == 3:
            return policy_bmm(a, b, pol)
        return policy_mm(a, b, pol)


def einsum(subscripts: str, a, b, *, policy=None, **overrides):
    """Policy-routed binary einsum (any two-operand contraction with no
    repeated indices — the framework's single GEMM chokepoint).  Same
    precedence rules as :func:`matmul`."""
    from repro.core.policy import get_policy, pdot
    cfg = _call_config(overrides)
    pol = get_policy(policy if policy is not None else cfg.policy)
    with _scoped(cfg):
        return pdot(subscripts, a, b, pol)


def attention(q, k, v, *, policy=None, q_pos=None, k_pos=None,
              causal: bool = True, window=0, softcap: float | None = None,
              **overrides):
    """Policy-routed scaled-dot-product attention.

    q ``(B, S, H, hd)``, k/v ``(B, T, Hkv, hd[v])`` with GQA by head
    grouping (``H % Hkv == 0``).  Routes to the fused TCEC flash-attention
    kernel when the active config allows, with the pdot composition as
    fallback and as the backward (recompute) path.  Positions default to
    ``arange``; same precedence rules as :func:`matmul`.
    """
    import jax.numpy as jnp
    from repro.core.policy import get_policy
    from repro.models import layers as L
    cfg = _call_config(overrides)
    pol = get_policy(policy if policy is not None else cfg.policy)
    B, S = q.shape[0], q.shape[1]
    T = k.shape[1]
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    class _Shim:
        mix_policy = pol
        attn_softcap = softcap

    with _scoped(cfg):
        return L.sdpa(q, k, v, _Shim, q_pos, k_pos, causal, window)


# ------------------------------------------------------------ CLI support

def parse_override_args(pairs) -> dict:
    """Parse CLI ``key=value`` pairs into :func:`use` overrides.

    Used by the launch binaries (``--numerics force=1 --numerics
    min_dim=0``).  Values are coerced by the target field's type: bools
    accept the registry's truthy/falsy spellings, ``none`` clears an
    optional field, tuples parse from comma-separated ints.
    """
    out = {}
    for pair in pairs or []:
        key, sep, raw = pair.partition("=")
        key = key.strip()
        if not sep or key not in _CONFIG_FIELDS:
            raise ValueError(
                f"bad --numerics override {pair!r}; expected key=value with "
                f"key in {sorted(_CONFIG_FIELDS)}")
        raw = raw.strip()
        if raw.lower() in ("none", ""):
            # only the genuinely-optional fields may be cleared
            if key not in ("block", "attn_block", "paged_block", "interpret"):
                raise ValueError(f"{key} cannot be set to none ({pair!r})")
            out[key] = None
        elif key in ("block", "attn_block"):
            out[key] = tuple(int(v) for v in raw.split(","))
        elif key in ("policy", "tune", "tune_cache"):
            out[key] = raw
        elif key in ("min_dim", "paged_block", "chunked_prefill"):
            out[key] = int(raw)
        elif raw.lower() in _TRUE:             # the bool fields
            out[key] = True
        elif raw.lower() in _FALSE:
            out[key] = False
        else:
            raise ValueError(f"bad boolean in override {pair!r}")
    return out


def add_cli_overrides(parser) -> None:
    """Register the shared ``--numerics KEY=VALUE`` argparse flag."""
    parser.add_argument(
        "--numerics", action="append", default=[], metavar="KEY=VALUE",
        help="numerics config override (repeatable), e.g. --numerics "
             "policy=tcec_bf16x6 --numerics enabled=false; keys are "
             "repro.numerics.NumericsConfig fields")


def cli_context(args):
    """The ``use(...)`` context for parsed CLI args (no-op when empty)."""
    return use(**parse_override_args(getattr(args, "numerics", None)))


# ----------------------------------------------------------- deprecations

def _deprecated(old: str, new: str):
    warnings.warn(f"{old} is deprecated; use {new}", DeprecationWarning,
                  stacklevel=3)


def _legacy_flag(name: str) -> bool:
    """Exact semantics of the retired ``dispatch.env_flag`` for its
    deprecation shim: truthy parse of ANY variable (registered or not),
    unset/empty/falsy spellings -> False, anything else -> True.  Lives
    here so the only environment reads in src/ stay in this module."""
    return os.environ.get(name, "").strip().lower() not in (
        "", "0", "false", "no", "off")
