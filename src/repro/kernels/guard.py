"""kernels.guard — a circuit breaker for fused-kernel dispatch.

The dispatch layer (:mod:`repro.kernels.dispatch`) always has a correct
answer available: the XLA term-expansion fallback computes the same
bit-specified result as the fused Pallas kernels, just slower.  That
makes kernel failures — a Mosaic lowering bug on an odd shape, a backend
regression, an interpret-mode edge case — *recoverable by construction*:
catch, fall back, keep serving.  What must NOT happen is paying the
failure cost (a raised exception deep inside a jit trace, possibly
seconds of compile time) on every single call for a shape that is known
to be broken.

Hence a classic circuit breaker, keyed by ``(backend, kernel,
shape-bucket...)`` so one pathological shape doesn't quarantine the
kernel wholesale:

* **closed** (healthy) — dispatch proceeds; consecutive failures are
  counted.
* **open** (quarantined) — after ``threshold`` consecutive failures the
  key is quarantined: :func:`allow` declines for ``cooldown`` subsequent
  calls, which dispatch turns into immediate XLA fallback (no retry
  cost).
* **half-open** (probing) — after the cooldown expires, exactly one call
  is allowed through as a probe.  Success closes the breaker; failure
  reopens it for another cooldown.

The cooldown is counted in *calls*, not wall-clock time — breaker
transitions are then a pure function of the call sequence, which keeps
the chaos battery (``tests/test_faults.py``) seed-deterministic and
avoids any clock read inside dispatch.

Failure-counting caveat: dispatch decisions happen at **trace time**.  A
jitted caller that hits its compiled cache never re-enters dispatch, so
the breaker sees one trace per (function, shape, config-epoch), not one
per execution.  That is the right granularity for the failures the
breaker exists to absorb (lowering/compile errors surface at trace
time), but it means runtime-only faults inside a cached executable are
invisible here — those are the engine's ``isfinite`` guard's job
(:mod:`repro.serving.engine`).

State is process-global (like the autotuner's in-memory cache) and
thread-safe; :func:`reset` restores a clean slate for tests.  The
``guard`` knob on :class:`repro.numerics.NumericsConfig` (env:
``REPRO_GUARD``) disables the whole mechanism, letting kernel errors
propagate for debugging.
"""
from __future__ import annotations

import threading

__all__ = ["THRESHOLD", "COOLDOWN", "make_key", "allow", "success",
           "failure", "state", "stats", "counters", "reset", "configure"]

# Consecutive failures that open a breaker, and how many declined calls
# an open breaker sits out before probing again.  Module-level (not per
# NumericsConfig) because breaker state itself is process-global.
THRESHOLD = 2
COOLDOWN = 8

_lock = threading.Lock()


class _Breaker:
    __slots__ = ("state", "consecutive_failures", "cooldown_left",
                 "failures", "successes", "declined", "opens", "closes",
                 "last_error")

    def __init__(self):
        self.state = "closed"
        self.consecutive_failures = 0
        self.cooldown_left = 0
        self.failures = 0
        self.successes = 0
        self.declined = 0
        self.opens = 0
        self.closes = 0
        self.last_error = None


_breakers: dict[tuple, _Breaker] = {}

# Process-wide health counters (aggregated over all keys, surviving
# reset of individual breakers only via reset()).
_totals = {"allowed": 0, "declined": 0, "failures": 0, "successes": 0,
           "opens": 0, "closes": 0, "half_opens": 0}


def configure(*, threshold: int | None = None,
              cooldown: int | None = None) -> None:
    """Adjust breaker parameters (tests; ops tuning).  Global."""
    global THRESHOLD, COOLDOWN
    with _lock:
        if threshold is not None:
            if threshold < 1:
                raise ValueError("threshold must be >= 1")
            THRESHOLD = threshold
        if cooldown is not None:
            if cooldown < 1:
                raise ValueError("cooldown must be >= 1")
            COOLDOWN = cooldown


def make_key(kernel: str, ident: tuple) -> tuple:
    """Breaker key: (backend, kernel, *ident).  ``ident`` is the
    dispatch site's shape-bucket tuple so quarantine stays per-shape."""
    import jax
    return (jax.default_backend(), kernel) + tuple(ident)


def _get(key: tuple) -> _Breaker:
    b = _breakers.get(key)
    if b is None:
        b = _breakers.setdefault(key, _Breaker())
    return b


def allow(key: tuple) -> bool:
    """Gate a dispatch attempt.  False = quarantined; the caller should
    take the XLA fallback immediately (and must NOT report success or
    failure for this call)."""
    with _lock:
        b = _get(key)
        if b.state == "open":
            if b.cooldown_left > 0:
                b.cooldown_left -= 1
                b.declined += 1
                _totals["declined"] += 1
                return False
            b.state = "half_open"
            _totals["half_opens"] += 1
        _totals["allowed"] += 1
        return True


def success(key: tuple) -> None:
    """Report a successful kernel call for ``key``."""
    with _lock:
        b = _get(key)
        b.successes += 1
        b.consecutive_failures = 0
        _totals["successes"] += 1
        if b.state != "closed":
            b.state = "closed"
            b.closes += 1
            _totals["closes"] += 1


def failure(key: tuple, exc: BaseException | None = None) -> None:
    """Report a failed kernel call for ``key``; may open the breaker."""
    with _lock:
        b = _get(key)
        b.failures += 1
        b.consecutive_failures += 1
        b.last_error = repr(exc) if exc is not None else None
        _totals["failures"] += 1
        # A half-open probe failure reopens immediately; a closed breaker
        # opens once consecutive failures reach the threshold.
        if b.state == "half_open" or b.consecutive_failures >= THRESHOLD:
            b.state = "open"
            b.cooldown_left = COOLDOWN
            b.opens += 1
            _totals["opens"] += 1


def state(key: tuple) -> str:
    """"closed" | "open" | "half_open" (unknown keys are closed)."""
    with _lock:
        b = _breakers.get(key)
        return b.state if b is not None else "closed"


def stats() -> dict:
    """Health snapshot: global totals plus per-key breaker detail for
    every key that has seen at least one failure or decline."""
    with _lock:
        keys = {}
        for key, b in _breakers.items():
            if b.failures or b.declined or b.state != "closed":
                keys["/".join(str(k) for k in key)] = {
                    "state": b.state,
                    "failures": b.failures,
                    "successes": b.successes,
                    "declined": b.declined,
                    "opens": b.opens,
                    "closes": b.closes,
                    "last_error": b.last_error,
                }
        return {"totals": dict(_totals), "threshold": THRESHOLD,
                "cooldown": COOLDOWN, "keys": keys}


def counters() -> dict:
    """Just the global totals (the bench snapshot records these)."""
    with _lock:
        return dict(_totals)


def reset() -> None:
    """Drop all breaker state and zero the totals (tests)."""
    with _lock:
        _breakers.clear()
        for k in _totals:
            _totals[k] = 0
