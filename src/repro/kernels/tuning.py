"""Measured block-shape autotuner for the TCEC kernel (paper §V discipline).

The paper's headline throughput only materializes after sweeping kernel
parameters under the shared-memory-capacity constraint (their Table 3 /
CUTLASS parameter sweep).  This module is the TPU analogue:

  * :func:`candidate_blocks` enumerates MXU-aligned ``(bm, bn, bk)`` triples
    that survive the VMEM-capacity filter (``vmem_bytes <= VMEM_BUDGET``);
  * :func:`autotune` times each surviving candidate on the real kernel
    (compiled on TPU; injectable measure function elsewhere) and picks the
    fastest;
  * winners persist to an on-disk JSON cache keyed by
    ``(backend, policy, shape-bucket)`` with an in-memory LRU in front, so
    tuned choices are reused across calls *and across processes*.

Cache format (see docs/kernels.md — "Autotuner cache"):

    {"version": 1,
     "entries": {"cpu/tcec_bf16x6/b1_m256_n256_k256":
                   {"block": [128, 128, 256], "ms": 0.41,
                    "source": "measured"}}}

Invalidation: delete the file, point the cache elsewhere, or bump
``CACHE_VERSION`` (version-mismatched files are ignored wholesale).

Tuning knobs live on :class:`repro.numerics.NumericsConfig` (see the env
registry in ``repro/numerics.py`` for the corresponding ``REPRO_TUNE*``
variables):

  * ``tune_cache`` — cache file path (default
    ``~/.cache/repro/tcec_autotune.json``).
  * ``tune="force"`` — measure even off-TPU (tests/bench).
  * ``tune="off"``   — never measure; heuristic only.
"""
from __future__ import annotations

import json
import os
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp

from repro import numerics
from repro.core.policy import get_policy
from .tcec_matmul import VMEM_BUDGET, tcec_matmul_pallas, vmem_bytes

CACHE_VERSION = 1
CANDIDATE_TILES = (128, 256, 512)


def cache_path(cfg=None) -> str:
    return (cfg or numerics.active()).tune_cache


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


def shape_bucket(B: int, M: int, N: int, K: int) -> tuple[int, int, int, int]:
    """Shapes are bucketed to their 128-padded dims: the kernel pads anyway,
    so two problems with the same padded shape share one tuned block."""
    return (max(1, B), _round_up(M, 128), _round_up(N, 128), _round_up(K, 128))


def heuristic_block(M: int, N: int, K: int,
                    policy_name: str) -> tuple[int, int, int]:
    """Largest MXU-aligned block that fits VMEM and divides the padded shape.

    The static fallback used when no measurement is available (and the
    baseline the benchmarks compare tuned choices against).
    """
    policy = get_policy(policy_name)
    best = (128, 128, 128)
    for bm in (512, 256, 128):
        for bn in (512, 256, 128):
            for bk in (512, 256, 128):
                if vmem_bytes((bm, bn, bk), policy) > VMEM_BUDGET:
                    continue
                # prefer blocks that don't overshoot the problem
                if bm <= max(M, 128) and bn <= max(N, 128) and bk <= max(K, 128):
                    cand = (bm, bn, bk)
                    if cand > best:
                        best = cand
    return best


def candidate_blocks(M: int, N: int, K: int, policy_name: str,
                     budget: int = VMEM_BUDGET) -> list[tuple[int, int, int]]:
    """MXU-aligned candidates under the VMEM budget, largest-first.

    Candidates overshooting the (128-padded) problem in any dim are dropped —
    they only add padding FLOPs, never throughput.
    """
    policy = get_policy(policy_name)
    _, pm, pn, pk = shape_bucket(1, M, N, K)
    out = []
    for bm in CANDIDATE_TILES:
        if bm > pm:
            continue
        for bn in CANDIDATE_TILES:
            if bn > pn:
                continue
            for bk in CANDIDATE_TILES:
                if bk > pk:
                    continue
                if vmem_bytes((bm, bn, bk), policy, has_bias=True) <= budget:
                    out.append((bm, bn, bk))
    out.sort(key=lambda b: (-(b[0] * b[1] * b[2]), b))
    return out or [(128, 128, 128)]


def valid_entry(entry) -> bool:
    """Schema check for one cache entry: ``{"block": [1-3 positive ints],
    "ms": None | number, ...}``.

    The cache file is shared, hand-editable state on disk — a truncated
    write, a stale schema, or plain corruption must read as a *miss* (the
    tuner re-derives the block), never as a malformed block tuple that
    trips the kernel's divisibility asserts inside a jit trace."""
    if not isinstance(entry, dict):
        return False
    block = entry.get("block")
    if not isinstance(block, (list, tuple)) or not 1 <= len(block) <= 3:
        return False
    if not all(type(v) is int and v > 0 for v in block):
        return False
    ms = entry.get("ms")
    return ms is None or isinstance(ms, (int, float))


class BlockCache:
    """On-disk JSON cache of measured block choices + in-memory LRU front.

    Reads are guarded: entries failing :func:`valid_entry` (and entries
    corrupted by the ``tuning.cache`` fault-injection site) are dropped
    and read as misses."""

    def __init__(self, path: str | None = None, capacity: int = 256):
        self.path = path or cache_path()
        self.capacity = capacity
        self._mem: OrderedDict[str, dict] = OrderedDict()
        self._disk: dict[str, dict] | None = None   # loaded lazily
        self._dirty: set[str] = set()               # keys THIS process wrote

    # ------------------------------------------------------------- disk io

    def _read_file(self) -> dict[str, dict]:
        try:
            with open(self.path) as f:
                data = json.load(f)
            if data.get("version") == CACHE_VERSION:
                return dict(data.get("entries", {}))
        except (OSError, ValueError):
            pass   # absent or corrupt file == empty cache
        return {}

    def _load_disk(self) -> dict[str, dict]:
        if self._disk is None:
            self._disk = self._read_file()
        return self._disk

    def _flush(self):
        # merge-on-write: re-read the file and overlay only the keys this
        # process measured, so concurrent tuners don't clobber each other's
        # entries (last-writer-wins per KEY, not per file)
        ours = self._load_disk()
        fresh = self._read_file()
        for key in self._dirty:
            if key in ours:
                fresh[key] = ours[key]
        fresh.update({k: v for k, v in ours.items() if k not in fresh})
        self._disk = fresh
        tmp = f"{self.path}.tmp.{os.getpid()}"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": fresh}, f, indent=1)
        os.replace(tmp, self.path)   # atomic: concurrent readers see old/new

    # ------------------------------------------------------------- lookups

    def get(self, key: str) -> dict | None:
        if key in self._mem:
            self._mem.move_to_end(key)
            entry = self._mem[key]
        else:
            entry = self._load_disk().get(key)
        if entry is not None:
            from repro import faults
            if faults.poke("tuning.cache") is not None:
                entry = {"block": "corrupt"}   # injected corruption
            if not valid_entry(entry):
                # corrupt entry == miss: drop it from both views so the
                # tuner re-derives (and eventually re-persists) the block
                self._mem.pop(key, None)
                self._load_disk().pop(key, None)
                return None
            if key not in self._mem:
                self._put_mem(key, entry)
        return entry

    def _put_mem(self, key: str, entry: dict):
        self._mem[key] = entry
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)

    def put(self, key: str, entry: dict, persist: bool):
        self._put_mem(key, entry)
        if persist:
            self._load_disk()[key] = entry
            self._dirty.add(key)
            self._flush()


_caches: dict[str, BlockCache] = {}


def get_cache(cfg=None) -> BlockCache:
    """One shared BlockCache per path: configs with different
    ``tune_cache`` paths interleave without thrashing each other's
    in-memory LRU."""
    path = cache_path(cfg)
    cache = _caches.get(path)
    if cache is None:
        cache = _caches[path] = BlockCache(path=path)
    return cache


def _ns(backend: str, namespace: str | None) -> str:
    """Key prefix: ``backend`` or ``backend/shmap`` (per-shard tuning —
    under a mesh the kernel runs the *local* tile, so winners live in
    their own namespace and never collide with same-shaped global
    problems)."""
    return backend if namespace is None else f"{backend}/{namespace}"


def cache_key(B: int, M: int, N: int, K: int, policy_name: str,
              backend: str, namespace: str | None = None) -> str:
    b, m, n, k = shape_bucket(B, M, N, K)
    return f"{_ns(backend, namespace)}/{policy_name}/b{b}_m{m}_n{n}_k{k}"


# ------------------------------------------------------------- measurement

def _should_measure(cfg=None) -> bool:
    mode = (cfg or numerics.active()).tune
    if mode == "off":
        return False
    if mode == "force":
        return True
    return jax.default_backend() == "tpu"


def _measure_block(B, M, N, K, policy_name, block, reps: int = 3,
                   interpret: bool | None = None) -> float:
    """Wall-clock one padded kernel call (ms, best of ``reps``)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm, bn, bk = block
    m, n, k = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    shape_a = (B, m, k) if B > 1 else (m, k)
    shape_b = (B, k, n) if B > 1 else (k, n)
    a = jnp.ones(shape_a, jnp.float32)
    b = jnp.ones(shape_b, jnp.float32)
    run = lambda: tcec_matmul_pallas(a, b, policy_name=policy_name,
                                     block=block, interpret=interpret)
    jax.block_until_ready(run())   # compile / warm up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def _autotune_protocol(key: str, heuristic, candidates, measure,
                       cache: BlockCache | None,
                       max_candidates: int | None) -> tuple[tuple, dict]:
    """The shared cache/measure/persist protocol behind every tuner:
    cache hit -> heuristic short-circuit (never persisted, so a later TPU
    process still measures) -> candidate sweep -> persist the winner.
    ``heuristic``/``candidates`` are thunks; ``measure`` is ``block -> ms``
    or None (meaning: measurement unavailable here)."""
    hit = cache.get(key)
    if hit is not None:
        return tuple(hit["block"]), {**hit, "source": "cache"}

    if measure is None:
        block = heuristic()
        entry = {"block": list(block), "ms": None, "source": "heuristic"}
        cache.put(key, entry, persist=False)
        return block, entry

    cands = candidates()
    if max_candidates:
        cands = cands[:max_candidates]
    timings = {blk: measure(blk) for blk in cands}
    block = min(timings, key=timings.get)
    entry = {"block": list(block), "ms": timings[block], "source": "measured"}
    cache.put(key, entry, persist=True)
    return block, {**entry, "timings": {str(k): v for k, v in timings.items()}}


def autotune(B: int, M: int, N: int, K: int, policy_name: str, *,
             measure=None, cache: BlockCache | None = None, reps: int = 3,
             max_candidates: int | None = None,
             interpret: bool | None = None,
             cfg=None, namespace: str | None = None
             ) -> tuple[tuple[int, int, int], dict]:
    """Pick a block for ``(B, M, N, K)`` under ``policy_name``.

    Returns ``(block, meta)`` where ``meta["source"]`` is one of
    ``"cache"`` (hit, in-memory or disk), ``"measured"`` (fresh sweep,
    persisted), or ``"heuristic"`` (no measurement available — not
    persisted, so a later TPU process still gets to measure).

    ``measure`` is injectable: a callable ``block -> milliseconds``.  When
    ``None``, real wall-clock measurement runs iff on TPU or the numerics
    config says ``tune="force"`` (env: ``REPRO_TUNE=1``).  ``cfg`` is the
    :class:`repro.numerics.NumericsConfig` governing tune mode and cache
    path (default: the active context).
    """
    if measure is None and _should_measure(cfg):
        measure = lambda blk: _measure_block(B, M, N, K, policy_name, blk,
                                             reps=reps, interpret=interpret)
    return _autotune_protocol(
        cache_key(B, M, N, K, policy_name, jax.default_backend(), namespace),
        heuristic=lambda: heuristic_block(M, N, K, policy_name),
        candidates=lambda: candidate_blocks(M, N, K, policy_name),
        measure=measure, cache=cache or get_cache(cfg),
        max_candidates=max_candidates)


def get_block(M: int, N: int, K: int, policy_name: str,
              batch: int = 1, cfg=None,
              namespace: str | None = None) -> tuple[int, int, int]:
    """The dispatch-facing entry: tuned block if available, else heuristic.

    ``namespace="shmap"`` keys the lookup on the per-shard shape under a
    mesh (``kernels/shmap.py`` passes the local tile dims here)."""
    block, _ = autotune(batch, M, N, K, policy_name, cfg=cfg,
                        namespace=namespace)
    return block


# ----------------------------------------------------- attention namespace
#
# The fused flash-attention kernel (kernels/tcec_attention.py) has its own
# (q_block, k_block) parameter space and its own VMEM working-set model
# (attn_vmem_bytes: Q/K/V tiles + split terms + the scores tile + per-group
# accumulators).  Entries share the same JSON cache file under a distinct
# "attn" key namespace, so GEMM and attention winners never collide.

ATTN_CANDIDATE_TILES = (128, 256, 512)


def attn_heuristic_block(S: int, T: int, rep: int, hd: int, hdv: int,
                         policy_name: str) -> tuple[int, int]:
    """Largest VMEM-feasible (bq, bk) — the static fallback when no
    measurement is available.  One definition of 'feasible': the head of
    the same filtered list the tuner sweeps."""
    return attn_candidate_blocks(S, T, rep, hd, hdv, policy_name)[0]


def attn_candidate_blocks(S: int, T: int, rep: int, hd: int, hdv: int,
                          policy_name: str,
                          budget: int = VMEM_BUDGET) -> list[tuple[int, int]]:
    """VMEM-feasible (bq, bk) candidates, largest-first."""
    from .tcec_attention import attn_vmem_bytes
    policy = get_policy(policy_name)
    ps, pt = _round_up(S, 128), _round_up(T, 128)
    out = []
    for bq in ATTN_CANDIDATE_TILES:
        if bq > ps:
            continue
        for bk in ATTN_CANDIDATE_TILES:
            if bk > pt:
                continue
            if attn_vmem_bytes((bq, bk), rep, hd, hdv, policy) <= budget:
                out.append((bq, bk))
    out.sort(key=lambda b: (-(b[0] * b[1]), b))
    return out or [(128, 128)]


def attn_cache_key(B: int, Hkv: int, rep: int, S: int, T: int, hd: int,
                   hdv: int, policy_name: str, backend: str,
                   causal: bool = True, namespace: str | None = None) -> str:
    s, t = _round_up(S, 128), _round_up(T, 128)
    d, dv = _round_up(hd, 128), _round_up(hdv, 128)
    # causal is part of the key: the kernel's block-level causal skip
    # halves the work, so causal and non-causal sweeps favor different
    # blocks for the same shape
    return (f"{_ns(backend, namespace)}/attn/{policy_name}/"
            f"b{max(1, B)}_h{max(1, Hkv)}_r{rep}_s{s}_t{t}_d{d}_v{dv}"
            f"_c{int(causal)}")


def _measure_attention(B, Hkv, rep, S, T, hd, hdv, policy_name, block,
                       reps: int = 3, interpret: bool | None = None,
                       causal: bool = True) -> float:
    """Wall-clock one padded attention kernel call (ms, best of ``reps``)."""
    from .tcec_attention import tcec_attention
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    a = jnp.ones((B, S, Hkv * rep, hd), jnp.float32)
    k = jnp.ones((B, T, Hkv, hd), jnp.float32)
    v = jnp.ones((B, T, Hkv, hdv), jnp.float32)
    run = lambda: tcec_attention(a, k, v, policy=policy_name, block=block,
                                 causal=causal, interpret=interpret)
    jax.block_until_ready(run())   # compile / warm up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def autotune_attention(B: int, Hkv: int, rep: int, S: int, T: int, hd: int,
                       hdv: int, policy_name: str, *, causal: bool = True,
                       measure=None, cache: BlockCache | None = None,
                       reps: int = 3, max_candidates: int | None = None,
                       interpret: bool | None = None, cfg=None,
                       namespace: str | None = None
                       ) -> tuple[tuple[int, int], dict]:
    """Attention-kernel analogue of :func:`autotune`: same cache file and
    protocol (``_autotune_protocol``), attention-specific key/candidates/
    measurement."""
    if measure is None and _should_measure(cfg):
        measure = lambda blk: _measure_attention(
            B, Hkv, rep, S, T, hd, hdv, policy_name, blk, reps=reps,
            interpret=interpret, causal=causal)
    return _autotune_protocol(
        attn_cache_key(B, Hkv, rep, S, T, hd, hdv, policy_name,
                       jax.default_backend(), causal, namespace),
        heuristic=lambda: attn_heuristic_block(S, T, rep, hd, hdv,
                                               policy_name),
        candidates=lambda: attn_candidate_blocks(S, T, rep, hd, hdv,
                                                 policy_name),
        measure=measure, cache=cache or get_cache(cfg),
        max_candidates=max_candidates)


def get_attention_block(B: int, Hkv: int, rep: int, S: int, T: int, hd: int,
                        hdv: int, policy_name: str,
                        causal: bool = True, cfg=None,
                        namespace: str | None = None) -> tuple[int, int]:
    """Dispatch-facing entry for the attention kernel's (bq, bk).
    ``namespace="shmap"`` keys on the per-shard shape (local tile)."""
    block, _ = autotune_attention(B, Hkv, rep, S, T, hd, hdv, policy_name,
                                  causal=causal, cfg=cfg,
                                  namespace=namespace)
    return block


# ------------------------------------------------------- paged namespace
#
# The paged decode-attention kernel (kernels/tcec_paged_attention.py) has a
# single tunable: ``pages_per_step`` — how many KV pages each grid step
# gathers through the block table into one (G*page_size)-column VMEM tile.
# Bigger G means larger MXU tiles and fewer grid steps but a bigger VMEM
# working set (paged_vmem_bytes is the capacity filter).  Winners share the
# same JSON cache file under the ``backend/paged/...`` key namespace.

PAGED_CANDIDATE_STEPS = (1, 2, 4, 8, 16, 32)


def paged_candidate_blocks(maxp: int, ps: int, rep: int, hd: int, hdv: int,
                           policy_name: str,
                           budget: int = VMEM_BUDGET) -> list[int]:
    """VMEM-feasible pages-per-step candidates, largest-first."""
    from .tcec_paged_attention import paged_vmem_bytes
    policy = get_policy(policy_name)
    out = [g for g in PAGED_CANDIDATE_STEPS
           if g <= max(1, maxp)
           and paged_vmem_bytes(g, ps, rep, hd, hdv, policy) <= budget]
    out.sort(reverse=True)
    return out or [1]


def paged_heuristic_block(maxp: int, ps: int, rep: int, hd: int, hdv: int,
                          policy_name: str) -> int:
    """Largest feasible G whose gathered tile reaches the 128-lane MXU
    (``G*ps >= 128`` when the page budget allows), else the feasible head."""
    cands = paged_candidate_blocks(maxp, ps, rep, hd, hdv, policy_name)
    aligned = [g for g in cands if g * ps >= 128]
    return (aligned[-1] if aligned else cands[0])


def paged_cache_key(B: int, Hkv: int, rep: int, maxp: int, ps: int, hd: int,
                    hdv: int, policy_name: str, backend: str,
                    namespace: str | None = None) -> str:
    d, dv = _round_up(hd, 128), _round_up(hdv, 128)
    return (f"{_ns(backend, namespace)}/paged/{policy_name}/"
            f"b{max(1, B)}_h{max(1, Hkv)}_r{rep}_p{max(1, maxp)}_ps{ps}"
            f"_d{d}_v{dv}")


def _measure_paged(B, Hkv, rep, maxp, ps, hd, hdv, policy_name, g,
                   reps: int = 3, interpret: bool | None = None) -> float:
    """Wall-clock one paged decode-attention call (ms, best of ``reps``)."""
    from .tcec_paged_attention import tcec_paged_attention
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    NP = max(2, B * maxp + 1)
    q = jnp.ones((B, Hkv * rep, hd), jnp.float32)
    kp = jnp.ones((NP, ps, Hkv, hd), jnp.bfloat16)
    vp = jnp.ones((NP, ps, Hkv, hdv), jnp.bfloat16)
    bt = (jnp.arange(B * maxp, dtype=jnp.int32).reshape(B, maxp) % (NP - 1)
          + 1)
    lens = jnp.full((B,), maxp * ps, jnp.int32)
    run = lambda: tcec_paged_attention(q, kp, vp, bt, lens,
                                       policy=policy_name, pages_per_step=g,
                                       interpret=interpret)
    jax.block_until_ready(run())   # compile / warm up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def autotune_paged(B: int, Hkv: int, rep: int, maxp: int, ps: int, hd: int,
                   hdv: int, policy_name: str, *, measure=None,
                   cache: BlockCache | None = None, reps: int = 3,
                   max_candidates: int | None = None,
                   interpret: bool | None = None,
                   cfg=None, namespace: str | None = None
                   ) -> tuple[int, dict]:
    """Paged-kernel analogue of :func:`autotune`: same cache file and
    protocol, pages-per-step candidate space.  Entries store the winner as
    a one-element ``block`` list so the JSON schema stays uniform."""
    if measure is None and _should_measure(cfg):
        measure = lambda g: _measure_paged(B, Hkv, rep, maxp, ps, hd, hdv,
                                           policy_name, g, reps=reps,
                                           interpret=interpret)
    wrapped = None if measure is None else (lambda blk: measure(blk[0]))
    block, meta = _autotune_protocol(
        paged_cache_key(B, Hkv, rep, maxp, ps, hd, hdv, policy_name,
                        jax.default_backend(), namespace),
        heuristic=lambda: (paged_heuristic_block(maxp, ps, rep, hd, hdv,
                                                 policy_name),),
        candidates=lambda: [(g,) for g in paged_candidate_blocks(
            maxp, ps, rep, hd, hdv, policy_name)],
        measure=wrapped, cache=cache or get_cache(cfg),
        max_candidates=max_candidates)
    return block[0], meta


def get_paged_block(B: int, Hkv: int, rep: int, maxp: int, ps: int, hd: int,
                    hdv: int, policy_name: str, cfg=None,
                    namespace: str | None = None) -> int:
    """Dispatch-facing entry for the paged kernel's pages-per-step.
    ``namespace="shmap"`` keys on the per-shard shape (local tile)."""
    g, _ = autotune_paged(B, Hkv, rep, maxp, ps, hd, hdv, policy_name,
                          cfg=cfg, namespace=namespace)
    return g
