"""Pure-jnp oracle for the TCEC matmul kernel.

An independent, loop-free restatement of the paper's corrected GEMM
(Eqs. 19-24 generalized to k-way bf16 splits): split both operands with RN
casts and residual scaling, run one lp-in/f32-out dot per kept product,
sum same-scale products in f32, fold the scaled epilogue smallest-first.
Also provides the f64 ground-truth GEMM used by Eq. (7) residuals.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.policy import get_policy


def tcec_matmul_ref(a, b, policy_name: str):
    """(M, K) @ (K, N) -> (M, N) f32 — the kernel's correctness oracle."""
    policy = get_policy(policy_name)
    a = jnp.asarray(a, dtype=jnp.float32)
    b = jnp.asarray(b, dtype=jnp.float32)
    scale = jnp.float32(2.0 ** policy.scale_bits)

    def splits(x):
        parts, r = [], x
        for i in range(policy.n_splits):
            p = r.astype(policy.jdtype)
            parts.append(p)
            if i + 1 < policy.n_splits:
                r = (r - p.astype(jnp.float32)) * scale
        return parts

    sa, sb = splits(a), splits(b)
    groups: dict[int, jnp.ndarray] = {}
    for (i, j) in policy.keep:
        t = jnp.dot(sa[i], sb[j], preferred_element_type=jnp.float32)
        g = i + j
        groups[g] = t if g not in groups else groups[g] + t
    keys = sorted(groups)
    out = groups[keys[-1]]
    inv = jnp.float32(2.0 ** (-policy.scale_bits))
    for g in reversed(keys[:-1]):
        out = groups[g] + out * inv
    return out


def tcec_bmm_ref(a, b, policy_name: str):
    """Batched oracle: (B, M, K) @ (B, K, N) -> (B, M, N) f32."""
    return jnp.stack([tcec_matmul_ref(a[i], b[i], policy_name)
                      for i in range(a.shape[0])])


def epilogue_ref(out, bias=None, activation: str | None = None,
                 out_scale: float = 1.0):
    """The fused kernel's scaled epilogue, restated with the same jnp ops
    the unfused model path uses: ``act(out * out_scale + bias)``."""
    from .tcec_matmul import EPILOGUE_ACTIVATIONS
    out = jnp.asarray(out, jnp.float32)
    if out_scale != 1.0:
        out = out * jnp.float32(out_scale)
    if bias is not None:
        out = out + jnp.asarray(bias, jnp.float32).reshape(1, -1)
    return EPILOGUE_ACTIVATIONS[activation](out)


def matmul_f64(a, b) -> np.ndarray:
    """Ground truth for Eq. (7) relative residuals."""
    return np.asarray(a, dtype=np.float64) @ np.asarray(b, dtype=np.float64)
