"""Fused TCEC flash-attention Pallas kernel.

One kernel computes the whole attention inner loop for a `(B, Hkv, q_block)`
grid cell — the paper's "no extra memory footprint" discipline applied one
level up from the GEMM:

  * K/V blocks stream HBM -> VMEM along the last (``arbitrary``) grid axis;
  * ``QK^T`` runs as the TCEC-split bf16 MXU passes (the ``_split_tile`` /
    kept-term schedule of ``tcec_matmul.py``) with the scale-group fold done
    in VMEM — the contraction dim (head_dim) is fully resident, so the fold
    happens immediately, exactly like the XLA term expansion;
  * scale, softcap, and the causal/window/tail mask apply to the scores tile
    **in VMEM** (the additive f32 bias of ``models.layers._mask_bias``);
  * the online softmax keeps running max/sum in VMEM scratch (flash
    attention; Markidis et al. arXiv:1803.04014 is why: Tensor-Core-era
    attention is bandwidth-bound, and the correction passes make the
    HBM round trip of a materialized scores tensor even more expensive);
  * ``P·V`` runs TCEC-split too, into one f32 VMEM accumulator per scale
    group (Code 3's frag_c / frag_dc), folded smallest-first on the last
    K step.

So the ``(S, T)`` scores/probs tensors never touch HBM, and causally
fully-masked K blocks are skipped inside the grid (``@pl.when`` on a
block-level predicate computed from the position tiles — the XLA
``blocked_attention`` fallback visits every chunk).

GQA runs by head grouping: the grid iterates KV heads and each q block
carries all ``rep = H // Hkv`` query heads of the group, so K/V are
fetched once per KV head and never materialized ``rep``-fold. The
``rep·bq`` query rows feed the MXU as one tall matmul.

Numerics contract (tests/test_attention.py): with a single K block covering
the whole (padded) KV length, the kernel normalizes the probs tile before
the ``P·V`` product — the exact operation sequence of the ``mha`` pdot
composition — and is **bit-identical** to it. Multi-block runs use the
online-softmax rescaling (per-group accumulators scaled by
``exp(m_old - m_new)``) and match the fallback to f32 tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policy import PrecisionPolicy, get_policy
from .tcec_matmul import VMEM_BUDGET, _split_tile

# Must match models.layers.NEG_INF: the additive mask bias is part of the
# bit-parity contract with the pdot-composition fallback (finite, so
# fully-masked rows produce garbage instead of NaN — same as the fallback).
NEG_INF = -2.0e38


def _compiler_params(semantics):
    """pltpu.CompilerParams across jax versions (TPUCompilerParams pre-0.5)."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=semantics)


def _contract(a, b, dims, upcast: bool):
    if upcast:
        # interpret mode: bf16 -> f32 is exact and two bf16-valued f32
        # factors multiply exactly in f32 — bit-identical to the MXU
        # contract (see tcec_matmul._kernel).
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.float32)


def _tcec_product(a, b, dims, policy: PrecisionPolicy, upcast: bool):
    """Split-term GEMM with the scale-group fold done immediately.

    Valid when the contraction dim is fully resident in VMEM (true for both
    attention products: head_dim for QK^T, the k-block for P·V within one
    grid step) — the fold order then matches ``_tcec_dot`` bit for bit."""
    sa = _split_tile(a, policy.n_splits, policy.scale_bits)
    sb = _split_tile(b, policy.n_splits, policy.scale_bits)
    parts: dict[int, jax.Array] = {}
    for (i, j) in policy.keep:
        t = _contract(sa[i], sb[j], dims, upcast)
        g = i + j
        parts[g] = t if g not in parts else parts[g] + t
    groups = policy.groups
    inv = jnp.float32(2.0 ** (-policy.scale_bits))
    out = parts[groups[-1]]
    for g in groups[-2::-1]:
        out = parts[g] + out * inv
    return out


# (lhs last dim) x (rhs last dim): QK^T contracts head_dim against head_dim
_QK_DIMS = (((1,), (1,)), ((), ()))
# plain row-major matmul: P (rows, bk) x V (bk, hdv)
_PV_DIMS = (((1,), (0,)), ((), ()))


def _attn_kernel(win_ref, q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                 m_ref, l_ref, *accs, policy: PrecisionPolicy, rep: int,
                 k_steps: int, causal: bool, softcap: float | None,
                 sm_denom: float, t_actual: int, upcast: bool):
    bq, hd = q_ref.shape[3], q_ref.shape[4]
    bk, hdv = k_ref.shape[2], v_ref.shape[3]
    rows = rep * bq
    ki = pl.program_id(3)
    groups = policy.groups

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        for acc in accs:
            acc[...] = jnp.zeros_like(acc)

    qp = qp_ref[0]                       # (bq,) i32 query positions
    kp = kp_ref[0]                       # (bk,) i32 key positions
    win = win_ref[0]                     # traced scalar; 0 = unlimited

    # ---- block-level skip: a K block masked for every (q, k) pair in the
    # tile contributes exactly zero probability mass, so skipping it is
    # numerically identical to the fallback's exp(-2e38 - m) underflow.
    col0 = ki * bk
    run = col0 < t_actual                            # padded KV tail
    if causal:
        run = jnp.logical_and(run, jnp.max(qp) >= jnp.min(kp))
    run = jnp.logical_and(                           # window: all d >= win
        run, jnp.logical_or(win <= 0, jnp.min(qp) - jnp.max(kp) < win))

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].reshape(rows, hd)
        s = _tcec_product(q, k_ref[0, 0], _QK_DIMS, policy, upcast)
        s = s / jnp.float32(sm_denom)
        if softcap:
            cap = jnp.float32(softcap)
            s = cap * jnp.tanh(s / cap)
        # additive f32 mask bias — models.layers._mask_bias, tile-local,
        # plus masking of the zero-padded KV tail
        qpr = jnp.broadcast_to(qp[None, :], (rep, bq)).reshape(rows, 1)
        d = qpr - kp[None, :]                        # (rows, bk)
        ok = (d >= 0) if causal else jnp.ones_like(d, dtype=bool)
        ok = jnp.logical_and(ok, jnp.where(win > 0, d < win, True))
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (rows, bk), 1)
        ok = jnp.logical_and(ok, cols < t_actual)
        s = s + jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)

        v = v_ref[0, 0]
        if k_steps == 1:
            # single-block path: the softmax completes here, so normalize
            # the probs tile *before* the split P·V product — the exact op
            # order of the mha fallback (bit-parity case).
            m = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - m)
            p = p / jnp.sum(p, axis=-1, keepdims=True)
            for gi, part in enumerate(_pv_parts(p, v, policy, upcast)):
                accs[gi][...] += part
        else:
            m_prev = m_ref[...]                      # (rows, 128) lane-bcast
            l_prev = l_ref[...]
            m_curr = jnp.max(s, axis=-1, keepdims=True)
            m_next = jnp.maximum(m_prev, m_curr)
            alpha = jnp.exp(m_prev - m_next)
            p = jnp.exp(s - m_next[:, :1])
            l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
            m_ref[...] = m_next
            a_col = alpha[:, :1]
            for gi, part in enumerate(_pv_parts(p, v, policy, upcast)):
                accs[gi][...] = accs[gi][...] * a_col + part

    @pl.when(ki == k_steps - 1)
    def _epilogue():
        inv = jnp.float32(2.0 ** (-policy.scale_bits))
        out = accs[len(groups) - 1][...]
        for gi in range(len(groups) - 2, -1, -1):
            out = accs[gi][...] + out * inv
        if k_steps > 1:
            out = out / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = out.reshape(rep, bq, hdv)


def _pv_parts(p, v, policy: PrecisionPolicy, upcast: bool):
    """Per-scale-group partial P·V products (unfolded: the caller owns the
    cross-K-block accumulators, fold happens in the epilogue)."""
    sp = _split_tile(p, policy.n_splits, policy.scale_bits)
    sv = _split_tile(v, policy.n_splits, policy.scale_bits)
    parts: dict[int, jax.Array] = {}
    for (i, j) in policy.keep:
        t = _contract(sp[i], sv[j], _PV_DIMS, upcast)
        g = i + j
        parts[g] = t if g not in parts else parts[g] + t
    return [parts[g] for g in policy.groups]


def attn_vmem_bytes(block: tuple[int, int], rep: int, hd: int, hdv: int,
                    policy: PrecisionPolicy) -> int:
    """VMEM working set of one attention grid step (the capacity filter the
    autotuner applies — same role as ``vmem_bytes`` for the GEMM kernel).

    ``hd``/``hdv`` are rounded up to the 128-lane MXU here so the filter
    judges the shapes the kernel actually runs — callers may pass unpadded
    model head dims."""
    bq, bk = block
    hd, hdv = _round_up(hd, 128), _round_up(hdv, 128)
    rows = rep * bq
    n = policy.n_splits
    tiles = 4 * (rows * hd + bk * hd + bk * hdv)          # f32 Q/K/V tiles
    splits = 2 * n * (rows * hd + bk * hd + bk * hdv)     # bf16 split terms
    scores = (4 + 2 * n) * rows * bk                      # f32 s/p + splits
    stats = 2 * rows * 128 * 4                            # m/l lane-bcast
    accum = len(policy.groups) * rows * hdv * 4           # f32 group accs
    out = rows * hdv * 4
    return tiles + splits + scores + stats + accum + out


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


@functools.partial(jax.jit, static_argnames=(
    "policy_name", "rep", "block", "causal", "softcap", "sm_denom",
    "t_actual", "interpret"))
def tcec_attention_pallas(q, k, v, q_pos, k_pos, window, *, policy_name: str,
                          rep: int, block: tuple[int, int],
                          causal: bool, softcap: float | None,
                          sm_denom: float, t_actual: int,
                          interpret: bool = False):
    """Fused attention on pre-padded, pre-transposed operands.

    q: (B, Hkv, rep, Sp, hd); k: (B, Hkv, Tp, hd); v: (B, Hkv, Tp, hdv);
    q_pos: (1, Sp) i32; k_pos: (1, Tp) i32; window: (1,) i32 (0 = off).
    Sp/Tp must be multiples of ``block``; returns (B, Hkv, rep, Sp, hdv) f32.
    """
    policy = get_policy(policy_name)
    assert not policy.is_plain(), "attention kernel is for split policies"
    B, Hkv, rep2, Sp, hd = q.shape
    Tp, hdv = k.shape[2], v.shape[3]
    assert rep2 == rep and k.shape[:2] == (B, Hkv) == v.shape[:2]
    bq, bk = block
    assert Sp % bq == 0 and Tp % bk == 0, (q.shape, k.shape, block)
    assert attn_vmem_bytes(block, rep, hd, hdv, policy) <= VMEM_BUDGET, \
        (block, rep, hd, hdv, policy.name)
    k_steps = Tp // bk
    grid = (B, Hkv, Sp // bq, k_steps)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = _compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary"))

    kern = functools.partial(
        _attn_kernel, policy=policy, rep=rep, k_steps=k_steps, causal=causal,
        softcap=softcap, sm_denom=sm_denom, t_actual=t_actual,
        upcast=interpret)
    rows = rep * bq
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                   # window
            pl.BlockSpec((1, 1, rep, bq, hd),
                         lambda b, h, qi, ki: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hdv),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, bq), lambda b, h, qi, ki: (0, qi)),
            pl.BlockSpec((1, bk), lambda b, h, qi, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, bq, hdv),
                               lambda b, h, qi, ki: (b, h, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, Sp, hdv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((rows, 128), jnp.float32),     # running m
                        pltpu.VMEM((rows, 128), jnp.float32)]     # running l
                       + [pltpu.VMEM((rows, hdv), jnp.float32)
                          for _ in policy.groups],
        interpret=interpret,
        **kwargs,
    )(window, q, k, v, q_pos, k_pos)


def _pad_axis(x, axis: int, mult: int):
    p = (-x.shape[axis]) % mult
    if not p:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, p)
    return jnp.pad(x, pads)


def tcec_attention(q, k, v, q_pos=None, k_pos=None, *,
                   policy: str = "tcec_bf16x6", causal: bool = True,
                   window=0, softcap: float | None = None,
                   block: tuple[int, int] | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """Public entry: fused TCEC attention on model-layout operands.

    q: (B, S, H, hd); k: (B, T, Hkv, hd); v: (B, T, Hkv, hdv); GQA via
    ``H = rep * Hkv``. ``q_pos``/``k_pos`` are (B, S)/(B, T) or (S,)/(T,)
    position vectors (batch-uniform, like the model layers; defaults to
    ``arange``). ``window`` may be a traced scalar (0 = unlimited).
    Shapes are padded internally: S/T to the block, head dims to the
    128-lane MXU (zero padding is exact — zero split terms contribute
    zero products, padded K columns are masked, padded V rows are zero).
    Returns (B, S, H, hdv) f32.
    """
    B, S, H, hd = q.shape
    T, Hkv, hdv = k.shape[1], k.shape[2], v.shape[3]
    assert H % Hkv == 0, (H, Hkv)
    rep = H // Hkv
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block is None:
        from . import tuning
        block = tuning.get_attention_block(B, Hkv, rep, S, T, hd, hdv, policy,
                                           causal=causal)
    bq, bk = block

    qt = q.astype(jnp.float32).reshape(B, S, Hkv, rep, hd)
    qt = jnp.transpose(qt, (0, 2, 3, 1, 4))          # (B, Hkv, rep, S, hd)
    kt = jnp.transpose(k.astype(jnp.float32), (0, 2, 1, 3))
    vt = jnp.transpose(v.astype(jnp.float32), (0, 2, 1, 3))
    qt = _pad_axis(_pad_axis(qt, 3, bq), 4, 128)
    kt = _pad_axis(_pad_axis(kt, 2, bk), 3, 128)
    vt = _pad_axis(_pad_axis(vt, 2, bk), 3, 128)

    def pos_row(p, n, mult):
        if p is None:
            p = jnp.arange(n, dtype=jnp.int32)
        p = jnp.asarray(p, jnp.int32)
        if p.ndim == 2:                              # batch-uniform, like mha
            p = p[0]
        return _pad_axis(p.reshape(1, n), 1, mult)

    qp = pos_row(q_pos, S, bq)
    kp = pos_row(k_pos, T, bk)
    win = jnp.asarray(0 if window is None else window, jnp.int32).reshape(1)

    out = tcec_attention_pallas(
        qt, kt, vt, qp, kp, win, policy_name=policy, rep=rep, block=block,
        causal=causal, softcap=(float(softcap) if softcap else None),
        sm_denom=float(np.sqrt(hd)), t_actual=T, interpret=interpret)
    out = jnp.transpose(out, (0, 3, 1, 2, 4))        # (B, Sp, Hkv, rep, hdv)
    return out[:, :S].reshape(B, S, H, out.shape[-1])[..., :hdv]
