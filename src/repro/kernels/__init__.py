"""TPU Pallas kernels for the paper's compute hot-spot: the error-corrected
single-precision GEMM itself (the paper's CUTLASS kernel, re-derived for the
bf16 MXU + VMEM memory hierarchy), plus the dispatch + autotuning subsystem
that routes every eligible framework contraction through it."""
from .ops import pick_block, tcec_matmul
from .ref import matmul_f64, tcec_bmm_ref, tcec_matmul_ref
from .tcec_matmul import (EPILOGUE_ACTIVATIONS, VMEM_BUDGET,
                          tcec_matmul_pallas, vmem_bytes)
from . import dispatch, tuning

__all__ = ["tcec_matmul", "pick_block", "tcec_matmul_ref", "tcec_bmm_ref",
           "matmul_f64", "tcec_matmul_pallas", "vmem_bytes", "VMEM_BUDGET",
           "EPILOGUE_ACTIVATIONS", "dispatch", "tuning"]
