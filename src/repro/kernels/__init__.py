"""TPU Pallas kernels for the paper's compute hot-spots: the error-corrected
single-precision GEMM itself (the paper's CUTLASS kernel, re-derived for the
bf16 MXU + VMEM memory hierarchy), the fused TCEC flash-attention kernel
built on the same split/term schedule, plus the dispatch + autotuning
subsystem that routes every eligible framework contraction through them."""
from .ops import pick_block, tcec_matmul
from .ref import matmul_f64, tcec_bmm_ref, tcec_matmul_ref
from .tcec_matmul import (EPILOGUE_ACTIVATIONS, VMEM_BUDGET,
                          tcec_matmul_pallas, vmem_bytes)
from .tcec_attention import (attn_vmem_bytes, tcec_attention,
                             tcec_attention_pallas)
from .tcec_paged_attention import (paged_vmem_bytes, tcec_paged_attention,
                                   tcec_paged_attention_pallas)
from . import dispatch, shmap, tuning

__all__ = ["tcec_matmul", "pick_block", "tcec_matmul_ref", "tcec_bmm_ref",
           "matmul_f64", "tcec_matmul_pallas", "vmem_bytes", "VMEM_BUDGET",
           "EPILOGUE_ACTIVATIONS", "tcec_attention", "tcec_attention_pallas",
           "attn_vmem_bytes", "tcec_paged_attention",
           "tcec_paged_attention_pallas", "paged_vmem_bytes", "dispatch",
           "shmap", "tuning"]
