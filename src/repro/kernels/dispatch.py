"""Unified kernel dispatch: routes framework contractions to the fused
Pallas TCEC kernel.

Every split-policy contraction in the framework funnels through
``repro.core.policy._dot_impl`` (``pdot`` / ``policy_mm`` / ``policy_bmm``
and their ``custom_vjp`` backward GEMMs).  This module decides, per call,
whether that contraction lowers to the fused Pallas kernel
(``kernels/tcec_matmul.py``) or stays on the documented XLA term-expansion
fallback.  Both paths compute the identical corrected-GEMM math — the
kernel just fuses it into one VMEM-tiled pass (the paper's CUTLASS
integration), which is where the throughput headline comes from.

Configuration comes from :mod:`repro.numerics`: every decision function
takes the frozen :class:`~repro.numerics.NumericsConfig` as an explicit
``cfg`` argument (defaulting to ``numerics.active()``, i.e. the innermost
``repro.numerics.use(...)`` context or the env defaults).  The decision
runs at trace time on static shapes, so under ``jit`` it costs nothing at
runtime — and because the active config's epoch is part of the jit cache
key, entering/exiting a ``use(...)`` context deterministically re-lowers
instead of silently reusing a stale decision (the old footgun).

Dispatch rules (see docs/kernels.md):

  1. the policy is a bf16 split policy (``tcec_bf16x3`` / ``tcec_bf16x6``):
     plain policies are a single XLA dot, and the fp16 reproduction
     policies model CUDA Tensor Cores, which the bf16 MXU kernel cannot;
  2. the contraction is 2-D or single-batch-dim 3-D with one m/n/k dim each
     (after ``pdot``'s canonical transpose this covers every model-zoo
     GEMM; multi-dim m/n einsums stay on XLA);
  3. M, N, K all reach ``min_dim`` (tiny GEMMs lose more to 128-padding
     than the fusion wins);
  4. the backend is TPU — or ``force`` is set, which runs the kernel in
     interpret mode (tests, CPU verification);
  5. the escape hatch is off: ``REPRO_DISABLE_PALLAS=1`` (or
     ``use(enabled=False)``) restores the XLA path wholesale;
  6. under an installed GSPMD mesh (``repro.parallel.ctx``), the
     ``shard_map`` knob is on (``use(shard_map=True)``, the default /
     ``REPRO_SHARD_MAP``) and ``kernels/shmap.py`` supports a per-shard
     spec for the shapes — the call then runs as per-device kernel shards
     under ``shard_map`` (K-sharded contractions fold locally, then one
     f32 ``psum``).  Unsupported specs (or the knob off) decline to the
     XLA fallback, which GSPMD shards natively.

The pre-``repro.numerics`` entry points (``override`` / ``config`` /
``reload_config`` / ``env_flag`` / ``DispatchConfig``) survive as thin
deprecation shims at the bottom of this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import numerics
from repro.core.policy import PrecisionPolicy
from repro.obs.explain import record as _explain
from . import ops, tuning


def _cfg(cfg) -> numerics.NumericsConfig:
    return cfg if cfg is not None else numerics.active()


def _policy_rule(policy: PrecisionPolicy) -> str:
    """Rule-1 decline slug: plain policies vs the fp16 reproduction
    policies (repro.obs.explain vocabulary)."""
    return "plain-policy" if policy.is_plain() else "policy-ineligible"


def _guarded(kernel: str, ident: tuple, cfg, thunk, site: str):
    """Run a fused-kernel thunk behind the circuit breaker.

    With the ``guard`` knob on (default), a kernel failure for this
    ``(backend, kernel, *ident)`` key is caught and converted to an XLA
    fallback (return None), and repeated failures quarantine the key for
    a cooldown (see :mod:`repro.kernels.guard`).  With the knob off the
    error propagates — the debugging posture.  ``site`` is the
    :mod:`repro.faults` injection point exercised by the chaos battery.

    NB this runs at trace time: a jitted caller consults the breaker
    once per (function, shape, config-epoch) trace, not per execution.
    Every outcome — launch, open breaker, kernel failure — lands in the
    explain table (``ident`` is ``(policy, *shape-bucket)`` at all three
    call sites, matching the explain key convention).
    """
    from repro import faults
    pol, bucket = str(ident[0]), tuple(ident[1:])
    if not cfg.guard:
        faults.raise_if(site)
        out = thunk()
        _explain(kernel, pol, bucket, "fused")
        return out
    from . import guard
    key = guard.make_key(kernel, ident)
    if not guard.allow(key):
        _explain(kernel, pol, bucket, "breaker-open")
        return None
    try:
        faults.raise_if(site)
        out = thunk()
    except Exception as exc:       # noqa: BLE001 — fallback exists by design
        guard.failure(key, exc)
        _explain(kernel, pol, bucket, "kernel-failure")
        return None
    guard.success(key)
    _explain(kernel, pol, bucket, "fused")
    return out


# ----------------------------------------------------------- eligibility

def eligible_policy(policy: PrecisionPolicy) -> bool:
    """Rule 1: bf16 split policies only.

    The fused kernels are parametric in the policy's term schedule
    (``keep`` / ``groups`` / ``n_splits``), so any bf16 multi-term policy
    (x3/x6/x10, ...) routes through them.  Three policy classes decline
    cleanly to the XLA expansion instead: plain policies (nothing to
    fuse), ``upcast_products`` policies (the fp16/fp8 reproduction paths
    assume full-precision products the kernel does not model), and
    ``compensated`` policies (error-free TwoSum accumulation has no MXU
    mapping — it is the accuracy extreme, not the throughput one)."""
    return (not policy.is_plain()
            and policy.jdtype == jnp.bfloat16
            and not policy.upcast_products
            and not policy.compensated)


def _canonicalize(a, b, dims):
    """Map a ``dot_general`` spec onto the kernel's ``(B?, M, K) @ (B?, K, N)``
    layout, or return None when the contraction doesn't fit (rule 2).

    Handles the backward GEMMs too: ``custom_vjp`` calls ``_dot_impl`` with
    the contraction on either operand side, so a transposed operand is
    swapped into canonical order here (the kernel output order matches
    ``dot_general``'s ``(batch, lhs-free, rhs-free)``).
    """
    (ca, cb), (ba, bb) = dims
    if len(ca) != 1 or len(cb) != 1:
        return None
    nb = len(ba)
    if nb > 1 or tuple(ba) != tuple(range(nb)) or tuple(bb) != tuple(range(nb)):
        return None
    if a.ndim != nb + 2 or b.ndim != nb + 2:
        return None
    if ca[0] == nb:            # contraction leads -> swap to (.., m, k)
        a = jnp.swapaxes(a, nb, nb + 1)
    elif ca[0] != nb + 1:
        return None
    if cb[0] == nb + 1:        # contraction trails -> swap to (.., k, n)
        b = jnp.swapaxes(b, nb, nb + 1)
    elif cb[0] != nb:
        return None
    return a, b


def _mesh_plan_or_decline(shapes_plan, cfg):
    """Rule 6: returns ``(mesh, plan)`` — ``(None, None)`` when no mesh is
    installed, or the string ``"decline"`` when a mesh is installed but
    the knob is off / the spec is unsupported (the caller falls back to
    XLA, which GSPMD shards natively)."""
    from repro.parallel import ctx
    mesh = ctx.current_mesh()
    if mesh is None:
        return None, None
    if not cfg.shard_map:
        return mesh, "decline"
    if "model" in ctx.dp_axes():
        # dp_over_model: the context declares "model" a *batch* axis
        # (small-model pure DP — parallel/sharding.py replicates params).
        # The plan builders would assign it to N/K/M instead, forcing an
        # all-gather on entry to every shard_map; pure DP is exactly what
        # the XLA fallback shards natively, so decline.
        return mesh, "decline"
    plan = shapes_plan(mesh)
    return mesh, (plan if plan is not None else "decline")


def _decide(a, b, policy: PrecisionPolicy, dims, cfg):
    """The rule walk: ``(canonical operands | None, rule slug)`` — the
    slug names the declining rule (repro.obs.explain vocabulary) or is
    ``"fused"`` on acceptance."""
    if not cfg.enabled:
        return None, "hatch-disabled"
    if not eligible_policy(policy):
        return None, _policy_rule(policy)
    if not (cfg.force or jax.default_backend() == "tpu"):
        return None, "off-backend"
    canon = _canonicalize(a, b, dims)
    if canon is None:
        return None, "shape-unsupported"
    at, bt = canon
    M, K = at.shape[-2], at.shape[-1]
    N = bt.shape[-1]
    if min(M, N, K) < cfg.min_dim:
        return None, "below-min-dim"
    from . import shmap
    _, plan = _mesh_plan_or_decline(
        lambda mesh: shmap.matmul_plan(at.shape, bt.shape, mesh), cfg)
    if plan == "decline":
        return None, "mesh-declined"
    return canon, "fused"


def decide(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """The GEMM dispatch decision, with the config threaded explicitly.

    Returns the canonicalized ``(a, b)`` operands when the contraction
    should lower to the fused kernel, or None for the XLA fallback.
    (Probing only — ``maybe_dispatch`` records the explain decision.)
    """
    canon, _ = _decide(a, b, policy, dims, _cfg(cfg))
    return canon


def maybe_dispatch(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """Return the fused-kernel result, or None to fall back to XLA.

    Called from ``repro.core.policy._dot_impl`` for every split-policy
    contraction (forward and backward).  Under an installed mesh the call
    runs per shard through the ``shard_map`` wrapper (rule 6).  Declines
    record their rule in the explain table here; launches record inside
    ``_guarded``.
    """
    cfg = _cfg(cfg)
    canon, rule = _decide(a, b, policy, dims, cfg)
    if canon is None:
        _explain("matmul", policy.name,
                 (tuple(a.shape), tuple(b.shape)), rule)
        return None
    at, bt = canon
    from . import shmap
    mesh, plan = _mesh_plan_or_decline(
        lambda m: shmap.matmul_plan(at.shape, bt.shape, m), cfg)
    M, K = at.shape[-2], at.shape[-1]
    N = bt.shape[-1]
    B = at.shape[0] if at.ndim == 3 else 1
    ident = (policy.name,) + tuning.shape_bucket(B, M, N, K)
    if mesh is not None:
        if plan == "decline":         # decide() screens this; stay graceful
            _explain("matmul", policy.name, ident[1:], "mesh-declined")
            return None
        return _guarded(
            "matmul", ident, cfg,
            lambda: shmap.sharded_matmul(at, bt, policy=policy.name,
                                         mesh=mesh, cfg=cfg, plan=plan),
            "kernel.matmul")

    def _run():
        block = tuned_block(M, N, K, policy.name, batch=B, cfg=cfg)
        return ops.tcec_matmul(at, bt, policy=policy.name, block=block,
                               interpret=cfg.interpret, cfg=cfg)
    return _guarded("matmul", ident, cfg, _run, "kernel.matmul")


# ------------------------------------------------- attention dispatch

def _attention_reason(q, k, v, pol, cfg) -> str:
    """The attention rule walk: ``"fused"`` when eligible, else the
    declining rule's explain slug."""
    if not cfg.enabled or not cfg.flash_attention:
        return "hatch-disabled"
    if not eligible_policy(pol):
        return _policy_rule(pol)
    if not (cfg.force or jax.default_backend() == "tpu"):
        return "off-backend"
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        return "shape-unsupported"
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    if (k.shape[0] != B or v.shape[:3] != k.shape[:3] or k.shape[3] != hd
            or Hkv == 0 or H % Hkv):
        return "shape-unsupported"
    if min(S, T) < cfg.min_dim:
        return "below-min-dim"
    from . import shmap
    _, plan = _mesh_plan_or_decline(
        lambda mesh: shmap.attention_plan(q.shape, k.shape, mesh), cfg)
    if plan == "decline":
        return "mesh-declined"
    # even the minimum (128, 128) block must fit VMEM — extreme-rep GQA
    # (rep ~ 100+ query heads per KV head) declines to the XLA path
    # instead of tripping the kernel's budget assert inside jit
    from .tcec_attention import attn_vmem_bytes
    from .tcec_matmul import VMEM_BUDGET
    if attn_vmem_bytes((128, 128), H // Hkv, hd, v.shape[3],
                       pol) > VMEM_BUDGET:
        return "vmem-budget"
    return "fused"


def attention_eligible(q, k, v, *, policy, cfg=None) -> bool:
    """Trace-time eligibility of the fused attention kernel for these
    operands.  True iff: split bf16 policy; TPU backend or ``force``;
    model-layout 4-D shapes with ``H % Hkv == 0``; ``min(S, T) >=
    min_dim``; both escape hatches off; and — under an installed GSPMD
    mesh — the ``shard_map`` knob is on and ``kernels/shmap.py`` has a
    per-shard spec for these shapes (head- or q-sequence-sharded), in
    which case the kernel runs per device under ``shard_map``.  An
    unsupported spec declines to the pdot fallbacks, which carry the
    context-parallel sharding constraints.

    Declines record their rule in the explain table here (the sdpa call
    sites pre-check eligibility and skip :func:`attention` entirely when
    False); acceptances record inside ``_guarded`` at launch.
    """
    from repro.core.policy import get_policy
    cfg = _cfg(cfg)
    pol = get_policy(policy)
    reason = _attention_reason(q, k, v, pol, cfg)
    if reason != "fused":
        _explain("attention", pol.name,
                 (tuple(q.shape), tuple(k.shape)), reason)
        return False
    return True


def attention(q, k, v, *, policy, q_pos=None, k_pos=None, causal: bool = True,
              window=0, softcap: float | None = None, cfg=None):
    """Route a model attention call to the fused TCEC flash-attention
    kernel, or return None for the pdot-composition fallback.

    Called from ``models.layers.sdpa`` (and the MLA / cross-attention
    variants) with model-layout operands: q ``(B, S, H, hd)``, k/v
    ``(B, T, Hkv, hd[v])``.  Eligibility mirrors :func:`decide`:
    split bf16 policy, TPU backend (or ``force`` -> interpret mode),
    ``min(S, T) >= min_dim``, and both escape hatches off
    (``REPRO_DISABLE_PALLAS`` disables all kernels,
    ``REPRO_DISABLE_FLASH_ATTN`` just this one).  ``window`` may be a
    traced scalar — it feeds the kernel as a runtime operand, so the
    decision never depends on its value.

    NB the raw kernel has no VJP: differentiated call sites must go
    through ``models.layers.sdpa`` (or ``_fused_sdpa``), whose custom_vjp
    recomputes the backward via the pdot composition.
    """
    from repro.core.policy import get_policy
    cfg = _cfg(cfg)
    pol = get_policy(policy)
    if not attention_eligible(q, k, v, policy=pol, cfg=cfg):
        return None
    from . import shmap
    mesh, plan = _mesh_plan_or_decline(
        lambda m: shmap.attention_plan(q.shape, k.shape, m), cfg)
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    ident = (pol.name, B, Hkv, H // Hkv,
             tuning._round_up(S, 128), tuning._round_up(T, 128))
    if mesh is not None:
        if plan == "decline":         # eligibility screens this; graceful
            _explain("attention", pol.name, ident[1:], "mesh-declined")
            return None
        return _guarded(
            "attention", ident, cfg,
            lambda: shmap.sharded_attention(q, k, v, q_pos, k_pos,
                                            policy=pol.name, causal=causal,
                                            window=window, softcap=softcap,
                                            mesh=mesh, cfg=cfg, plan=plan),
            "kernel.attention")

    def _run():
        from .tcec_attention import tcec_attention
        block = cfg.attn_block
        if block is None:
            block = tuning.get_attention_block(B, Hkv, H // Hkv, S, T, hd,
                                               v.shape[3], pol.name,
                                               causal=causal, cfg=cfg)
        return tcec_attention(q, k, v, q_pos, k_pos, policy=pol.name,
                              causal=causal, window=window, softcap=softcap,
                              block=block, interpret=cfg.interpret)
    return _guarded("attention", ident, cfg, _run, "kernel.attention")


# -------------------------------------------- paged decode-attention
#
# Decode-time attention against the serving engine's paged KV cache
# (serving/kv_cache.py): K/V live in fixed-size pages of a shared pool,
# addressed through per-sequence block tables.  The fused kernel
# (kernels/tcec_paged_attention.py) gathers the pages via scalar-prefetch
# BlockSpecs and runs TCEC-split QK^T / P·V; the fallback (the caller's
# gather + ``attention_decode`` math) is the verification oracle.

def attention_decode_eligible(q, k_pages, v_pages, *, policy,
                              cfg=None) -> bool:
    """Trace-time eligibility of the paged decode-attention kernel.

    True iff: split bf16 policy; TPU backend or ``force``; decode-layout
    shapes — q ``(B, H, hd)``, pools ``(NP, ps, Hkv, hd[v])`` with
    ``H % Hkv == 0``; a single page fits VMEM; the hatches are off
    (``REPRO_DISABLE_PALLAS`` wholesale, ``REPRO_DISABLE_PAGED_ATTN``
    granular); and — under an installed GSPMD mesh — the ``shard_map``
    knob is on and ``kernels/shmap.py`` supports the layout (KV heads on
    ``model``, batch on the data axes; block tables stay device-local).
    No ``min_dim`` gate: decode rows are ``rep``-tall by construction —
    the page gather, not the tile size, is the point.
    """
    from repro.core.policy import get_policy
    cfg = _cfg(cfg)
    pol = get_policy(policy)
    reason = _paged_reason(q, k_pages, v_pages, pol, cfg)
    if reason != "fused":
        _explain("paged_attention", pol.name,
                 (tuple(q.shape), tuple(k_pages.shape)), reason)
        return False
    return True


def _paged_reason(q, k_pages, v_pages, pol, cfg) -> str:
    """The paged decode-attention rule walk: ``"fused"`` when eligible,
    else the declining rule's explain slug."""
    if not cfg.enabled or not cfg.paged_attention:
        return "hatch-disabled"
    if not eligible_policy(pol):
        return _policy_rule(pol)
    if not (cfg.force or jax.default_backend() == "tpu"):
        return "off-backend"
    if q.ndim != 3 or k_pages.ndim != 4 or v_pages.ndim != 4:
        return "shape-unsupported"
    B, H, hd = q.shape
    NP, ps, Hkv, hd2 = k_pages.shape
    if (hd2 != hd or v_pages.shape[:3] != k_pages.shape[:3]
            or Hkv == 0 or H % Hkv):
        return "shape-unsupported"
    from . import shmap
    _, plan = _mesh_plan_or_decline(
        lambda mesh: shmap.paged_plan(q.shape, k_pages.shape, mesh), cfg)
    if plan == "decline":
        return "mesh-declined"
    from .tcec_paged_attention import paged_vmem_bytes
    from .tcec_matmul import VMEM_BUDGET
    if paged_vmem_bytes(1, ps, H // Hkv, hd, v_pages.shape[3],
                        pol) > VMEM_BUDGET:
        return "vmem-budget"
    return "fused"


def attention_decode(q, k_pages, v_pages, block_tables, lengths, *, policy,
                     window=0, softcap: float | None = None, cfg=None):
    """Route a paged decode-attention call to the fused kernel, or return
    None for the gather-and-attend fallback.

    Called from ``models.layers.attention_decode_paged`` with one query
    token per sequence slot: q ``(B, H, hd)``, pools ``(NP, ps, Hkv,
    hd[v])``, ``block_tables`` ``(B, maxp)`` i32, ``lengths`` ``(B,)`` i32
    counting valid tokens *including* the current one (whose K/V must
    already be written to its page).  ``window`` may be a traced scalar.

    NB the kernel is **more accurate** than the fallback: it TCEC-splits
    the f32 query and probs where the dense decode path rounds both to
    bf16 (tests/test_serving.py asserts the ordering against an f32
    oracle).  ``REPRO_DISABLE_PAGED_ATTN=1`` restores exact dense parity.
    """
    from repro.core.policy import get_policy
    cfg = _cfg(cfg)
    pol = get_policy(policy)
    if not attention_decode_eligible(q, k_pages, v_pages, policy=pol,
                                     cfg=cfg):
        return None
    from . import shmap
    mesh, plan = _mesh_plan_or_decline(
        lambda m: shmap.paged_plan(q.shape, k_pages.shape, m), cfg)
    B, H, hd = q.shape
    NP, ps, Hkv, _ = k_pages.shape
    ident = (pol.name, B, Hkv, H // Hkv, block_tables.shape[1], ps)
    if mesh is not None:
        if plan == "decline":         # eligibility screens this; graceful
            _explain("paged_attention", pol.name, ident[1:], "mesh-declined")
            return None
        return _guarded(
            "paged_attention", ident, cfg,
            lambda: shmap.sharded_paged_attention(
                q, k_pages, v_pages, block_tables, lengths, policy=pol.name,
                window=window, softcap=softcap, mesh=mesh, cfg=cfg,
                plan=plan),
            "kernel.paged")

    def _run():
        from .tcec_paged_attention import tcec_paged_attention
        g = cfg.paged_block
        if g is None:
            g = tuning.get_paged_block(B, Hkv, H // Hkv,
                                       block_tables.shape[1], ps, hd,
                                       v_pages.shape[3], pol.name, cfg=cfg)
        return tcec_paged_attention(q, k_pages, v_pages, block_tables,
                                    lengths, policy=pol.name, window=window,
                                    softcap=softcap, pages_per_step=g,
                                    interpret=cfg.interpret)
    return _guarded("paged_attention", ident, cfg, _run, "kernel.paged")


# ------------------------------------------------- epilogue-fusion hook

def epilogue_eligible(policy: PrecisionPolicy, cfg=None) -> bool:
    """Whether ``models.layers.fused_linear`` may fold its bias/activation
    into the kernel's scaled epilogue under the given config.

    Declines under an installed GSPMD mesh: the fused path flattens
    ``(B, S, D) -> (B*S, D)``, and that reshape replicates a sharded
    batch dim under GSPMD — the unfused pdot path dispatches through the
    ``shard_map`` wrapper instead (same GEMMs, unfused epilogue).

    Records every decision (shape-independent, so the bucket is empty);
    the GEMM underneath still records its own matmul decision."""
    from repro.parallel import ctx
    cfg = _cfg(cfg)
    if not cfg.enabled or not cfg.fuse_epilogue:
        rule = "hatch-disabled"
    elif not eligible_policy(policy):
        rule = _policy_rule(policy)
    elif ctx.current_mesh() is not None:
        rule = "mesh-declined"
    elif not (cfg.force or jax.default_backend() == "tpu"):
        rule = "off-backend"
    else:
        rule = "fused"
    _explain("epilogue", policy.name, (), rule)
    return rule == "fused"


def tuned_block(M: int, N: int, K: int, policy_name: str,
                batch: int = 1, cfg=None) -> tuple[int, int, int]:
    """Config override if set, else the autotuner (measured or heuristic)."""
    cfg = _cfg(cfg)
    if cfg.block is not None:
        return cfg.block
    return tuning.get_block(M, N, K, policy_name, batch=batch, cfg=cfg)


# ------------------------------------------------------ deprecation shims
#
# The pre-repro.numerics surface.  Each shim emits exactly one
# DeprecationWarning and delegates; tests/test_deprecation.py runs them
# under -W error::DeprecationWarning to pin the warning set.

def override(**kw):
    """Deprecated: use ``repro.numerics.use(...)``."""
    numerics._deprecated("repro.kernels.dispatch.override()",
                         "repro.numerics.use()")
    return numerics.use(**kw)


def config() -> numerics.NumericsConfig:
    """Deprecated: use ``repro.numerics.active()``."""
    numerics._deprecated("repro.kernels.dispatch.config()",
                         "repro.numerics.active()")
    return numerics.active()


def reload_config() -> numerics.NumericsConfig:
    """Deprecated: use ``repro.numerics.reload_env_defaults()``."""
    numerics._deprecated("repro.kernels.dispatch.reload_config()",
                         "repro.numerics.reload_env_defaults()")
    return numerics.reload_env_defaults()


def env_flag(name: str) -> bool:
    """Deprecated: use ``repro.numerics.env_value(name)``."""
    numerics._deprecated("repro.kernels.dispatch.env_flag()",
                         "repro.numerics.env_value()")
    return numerics._legacy_flag(name)


def __getattr__(name):
    if name == "DispatchConfig":
        numerics._deprecated("repro.kernels.dispatch.DispatchConfig",
                             "repro.numerics.NumericsConfig")
        return numerics.NumericsConfig
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
