"""Public entry point for the TCEC matmul kernel.

Handles backend dispatch (compiled on TPU, ``interpret=True`` elsewhere),
padding to MXU-aligned block multiples, batched operands, the fused
bias/activation epilogue, and block-shape selection (measured autotuner in
``tuning.py``, VMEM-filtered heuristic as fallback).  Callers that want the
technique without caring about kernels should use :func:`repro.core.pdot`,
which routes eligible contractions here automatically via
``kernels/dispatch.py`` and falls back to the XLA term expansion elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import numerics
from .tcec_matmul import VMEM_BUDGET, tcec_matmul_pallas, vmem_bytes
from . import tuning


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_block(M: int, N: int, K: int, policy_name: str) -> tuple[int, int, int]:
    """Deprecated: use ``repro.tuning.heuristic_block``."""
    numerics._deprecated("repro.kernels.ops.pick_block()",
                         "repro.tuning.heuristic_block()")
    return tuning.heuristic_block(M, N, K, policy_name)


def _pad_dims(x, dims_to_mult: dict[int, int]):
    pads = [(0, 0)] * x.ndim
    any_pad = False
    for axis, m in dims_to_mult.items():
        p = (-x.shape[axis]) % m
        pads[axis] = (0, p)
        any_pad |= p > 0
    return jnp.pad(x, pads) if any_pad else x


def tcec_matmul(a: jax.Array, b: jax.Array, policy: str = "tcec_bf16x6",
                block: tuple[int, int, int] | None = None,
                interpret: bool | None = None, bias: jax.Array | None = None,
                activation: str | None = None,
                out_scale: float = 1.0, cfg=None) -> jax.Array:
    """FP32-accurate GEMM on the bf16 MXU via the fused TCEC kernel.

    ``(M, K) @ (K, N) -> (M, N)`` or batched ``(B, M, K) @ (B, K, N) ->
    (B, M, N)``, any shapes (padded internally to block multiples).  The
    optional fused epilogue computes ``act(out * out_scale + bias)`` inside
    the kernel (``bias`` shaped ``(N,)`` or ``(1, N)``).

    ``block`` and ``interpret`` default from ``cfg`` (a
    :class:`repro.numerics.NumericsConfig`; callers like
    ``dispatch.maybe_dispatch`` thread theirs through, otherwise the
    active context's): an explicit argument wins, then the config's
    override, then the autotuner (measured winner from the on-disk cache
    when available, VMEM-filtered heuristic otherwise — see
    ``kernels/tuning.py``) and backend autodetection.
    """
    if cfg is None:
        cfg = numerics.active()
    batched = a.ndim == 3
    assert a.ndim == b.ndim, (a.shape, b.shape)
    if batched:
        B, M, K = a.shape
        B2, K2, N = b.shape
        assert B == B2, (a.shape, b.shape)
    else:
        B = 1
        M, K = a.shape
        K2, N = b.shape
    # must reject BEFORE padding — zero-padding would silently "align"
    # mismatched contraction dims into a wrong-but-finite result
    assert K == K2, (a.shape, b.shape)
    if interpret is None:
        interpret = cfg.interpret
    if interpret is None:
        interpret = not _on_tpu()
    if block is None:
        block = cfg.block
    if block is None:
        block = tuning.get_block(M, N, K, policy, batch=B, cfg=cfg)
    bm, bn, bk = block
    nd = a.ndim
    ap = _pad_dims(a.astype(jnp.float32), {nd - 2: bm, nd - 1: bk})
    bp = _pad_dims(b.astype(jnp.float32), {nd - 2: bk, nd - 1: bn})
    bp2 = None
    if bias is not None:
        bias2 = jnp.asarray(bias, jnp.float32).reshape(1, N)
        bp2 = _pad_dims(bias2, {1: bn})
    out = tcec_matmul_pallas(ap, bp, bp2, policy_name=policy, block=block,
                             interpret=interpret, activation=activation,
                             out_scale=out_scale)
    return out[..., :M, :N]
