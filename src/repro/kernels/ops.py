"""Public jit'd entry point for the TCEC matmul kernel.

Handles backend dispatch (compiled on TPU, ``interpret=True`` elsewhere),
padding to MXU-aligned block multiples, and block-shape selection under the
VMEM budget.  Callers that want the technique without caring about kernels
should use :func:`repro.core.pdot`, which lowers to the same math at the XLA
level; this wrapper is the explicit-kernel path benchmarked in §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .tcec_matmul import VMEM_BUDGET, tcec_matmul_pallas, vmem_bytes
from repro.core.policy import get_policy


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_block(M: int, N: int, K: int, policy_name: str) -> tuple[int, int, int]:
    """Largest MXU-aligned block that fits VMEM and divides the padded shape."""
    policy = get_policy(policy_name)
    best = (128, 128, 128)
    for bm in (512, 256, 128):
        for bn in (512, 256, 128):
            for bk in (512, 256, 128):
                if vmem_bytes((bm, bn, bk), policy) > VMEM_BUDGET:
                    continue
                # prefer blocks that don't overshoot the problem
                if bm <= max(M, 128) and bn <= max(N, 128) and bk <= max(K, 128):
                    cand = (bm, bn, bk)
                    if cand > best:
                        best = cand
    return best


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


@functools.partial(jax.jit, static_argnames=("policy", "block", "interpret"))
def tcec_matmul(a: jax.Array, b: jax.Array, policy: str = "tcec_bf16x6",
                block: tuple[int, int, int] | None = None,
                interpret: bool | None = None) -> jax.Array:
    """FP32-accurate (M,K)@(K,N) on the bf16 MXU via the fused TCEC kernel."""
    M, K = a.shape
    _, N = b.shape
    if interpret is None:
        interpret = not _on_tpu()
    if block is None:
        block = pick_block(M, N, K, policy)
    ap = _pad_to(a.astype(jnp.float32), block[0], block[2])
    bp = _pad_to(b.astype(jnp.float32), block[2], block[1])
    out = tcec_matmul_pallas(ap, bp, policy_name=policy, block=block,
                             interpret=interpret)
    return out[:M, :N]
