"""Fused TCEC paged decode-attention Pallas kernel.

Decode-time attention against a **paged** KV cache: each sequence's keys
and values live in fixed-size pages of a shared pool, addressed through a
per-sequence block table (the vLLM PagedAttention layout, TPU-native).
This is the one serving hot path that still bypassed the TCEC kernels —
and, per Markidis et al. (arXiv:1803.04014), the one where matrix-unit
throughput only materializes if the gather feeds the MMA tiles directly
instead of round-tripping a defragmented copy through HBM.

One kernel invocation computes a whole decode step for a ``(B, Hkv)``
grid cell, streaming the sequence's pages along the last (``arbitrary``)
grid axis:

  * the block table and sequence lengths ride in SMEM via
    ``PrefetchScalarGridSpec`` — the BlockSpec index maps read
    ``block_table[b, step * G + j]`` to DMA the right pages from the pool
    into VMEM, so the gather *is* the tile fetch (no gathered copy of the
    cache is ever materialized in HBM);
  * ``pages_per_step`` (``G``) pages are fetched per grid step — one
    BlockSpec per page — and concatenated in VMEM into a ``(G·ps)``-column
    KV tile, the kernel's tunable (``kernels/tuning.py``, the
    ``backend/paged/...`` cache namespace);
  * ``QK^T`` and ``P·V`` run TCEC-split (``_split_tile`` and the kept-term
    schedule of ``tcec_matmul.py``) with per-scale-group f32 VMEM
    accumulators folded smallest-first in the epilogue — the same
    correction discipline as the prefill flash-attention kernel;
  * the online softmax keeps running max/sum in VMEM scratch; pages wholly
    past the sequence length (or wholly outside the sliding window) are
    skipped via ``@pl.when`` on a block-level predicate.

Numerics: the fallback decode path (``models.layers.attention_decode``)
computes its cache dots in plain bf16 — the query and the probabilities
are *rounded to bf16* before the MXU. This kernel instead splits the f32
query and the f32 probs tile (the cache itself is bf16-valued, so its
first split term is exact and the residual terms vanish), recovering the
precision the dense path discards — the paper's correction applied at
decode time.  Tests assert the kernel sits closer to an f32 oracle than
the bf16 fallback does, and matches the fallback to bf16-level tolerance.

Masking is a **select**, not an additive bias: recycled pages may hold
stale garbage from finished requests, and ``garbage + NEG_INF`` could
stay non-finite (see ``attention_decode``'s O(T) validity select).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policy import PrecisionPolicy, get_policy
from .tcec_matmul import VMEM_BUDGET, _split_tile  # noqa: F401 (re-export)
from .tcec_attention import (NEG_INF, _QK_DIMS, _compiler_params,
                             _pv_parts, _round_up, _tcec_product)


def _paged_kernel(tbl_ref, len_ref, win_ref, q_ref, *refs,
                  policy: PrecisionPolicy, rep: int, pages: int,
                  n_steps: int, softcap: float | None, sm_denom: float,
                  upcast: bool):
    k_refs = refs[:pages]
    v_refs = refs[pages:2 * pages]
    o_ref = refs[2 * pages]
    m_ref, l_ref, *accs = refs[2 * pages + 1:]
    ps, hd = k_refs[0].shape[1], k_refs[0].shape[3]
    hdv = v_refs[0].shape[3]
    cols = pages * ps
    b = pl.program_id(0)
    i = pl.program_id(2)
    groups = policy.groups

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        for acc in accs:
            acc[...] = jnp.zeros_like(acc)

    length = len_ref[b]                  # valid tokens incl. current
    cur = length - 1                     # position of the current token
    win = win_ref[0]                     # traced scalar; 0 = unlimited
    col0 = i * cols

    # ---- block-level skip: pages wholly past the sequence length, or
    # wholly older than the sliding window, contribute zero mass.
    run = col0 < length
    run = jnp.logical_and(
        run, jnp.logical_or(win <= 0, cur - (col0 + cols - 1) < win))

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (rep, hd)
        kt = jnp.concatenate(
            [k_refs[j][0, :, 0, :].astype(jnp.float32) for j in range(pages)],
            axis=0)                                      # (cols, hd)
        vt = jnp.concatenate(
            [v_refs[j][0, :, 0, :].astype(jnp.float32) for j in range(pages)],
            axis=0)                                      # (cols, hdv)
        s = _tcec_product(q, kt, _QK_DIMS, policy, upcast)
        s = s / jnp.float32(sm_denom)
        if softcap:
            cap = jnp.float32(softcap)
            s = cap * jnp.tanh(s / cap)
        # validity select (not an additive bias): recycled pages hold
        # stale finite-or-not garbage that must not leak through
        pos = col0 + jax.lax.broadcasted_iota(jnp.int32, (rep, cols), 1)
        ok = pos <= cur
        ok = jnp.logical_and(ok, jnp.where(win > 0, cur - pos < win, True))
        s = jnp.where(ok, s, jnp.float32(NEG_INF))

        if n_steps == 1:
            # single-step: the softmax completes here — normalize the
            # probs tile before the split P·V (the fallback's op order)
            m = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - m)
            p = p / jnp.sum(p, axis=-1, keepdims=True)
            for gi, part in enumerate(_pv_parts(p, vt, policy, upcast)):
                accs[gi][...] += part
        else:
            m_prev = m_ref[...]                          # (rep, 128)
            l_prev = l_ref[...]
            m_curr = jnp.max(s, axis=-1, keepdims=True)
            m_next = jnp.maximum(m_prev, m_curr)
            alpha = jnp.exp(m_prev - m_next)
            p = jnp.exp(s - m_next[:, :1])
            l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
            m_ref[...] = m_next
            a_col = alpha[:, :1]
            for gi, part in enumerate(_pv_parts(p, vt, policy, upcast)):
                accs[gi][...] = accs[gi][...] * a_col + part

    @pl.when(i == n_steps - 1)
    def _epilogue():
        inv = jnp.float32(2.0 ** (-policy.scale_bits))
        out = accs[len(groups) - 1][...]
        for gi in range(len(groups) - 2, -1, -1):
            out = accs[gi][...] + out * inv
        if n_steps > 1:
            out = out / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = out


def paged_vmem_bytes(pages_per_step: int, page_size: int, rep: int, hd: int,
                     hdv: int, policy: PrecisionPolicy) -> int:
    """VMEM working set of one paged-attention grid step (the capacity
    filter the ``backend/paged`` autotuner applies).  Head dims and the
    gathered column count are rounded to the 128-lane MXU; the query rows
    to the f32 8-sublane tile."""
    hd, hdv = _round_up(hd, 128), _round_up(hdv, 128)
    rows = _round_up(rep, 8)
    cols = _round_up(pages_per_step * page_size, 128)
    n = policy.n_splits
    pages = 2 * pages_per_step * page_size * (hd + hdv)   # bf16 page tiles
    tiles = 4 * (rows * hd + cols * hd + cols * hdv)      # f32 Q/K/V tiles
    splits = 2 * n * (rows * hd + cols * hd + cols * hdv)
    scores = (4 + 2 * n) * rows * cols                    # f32 s/p + splits
    stats = 2 * rows * 128 * 4                            # m/l lane-bcast
    accum = len(policy.groups) * rows * hdv * 4
    out = rows * hdv * 4
    return pages + tiles + splits + scores + stats + accum + out


@functools.partial(jax.jit, static_argnames=(
    "policy_name", "rep", "pages_per_step", "softcap", "sm_denom",
    "interpret"))
def tcec_paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                window, *, policy_name: str, rep: int,
                                pages_per_step: int,
                                softcap: float | None, sm_denom: float,
                                interpret: bool = False):
    """Paged decode attention on pool-layout operands.

    q: (B, Hkv, rep, hd) f32; k_pages: (NP, ps, Hkv, hd); v_pages:
    (NP, ps, Hkv, hdv) (any float dtype — pages are upcast per tile);
    block_tables: (B, maxp) i32 page indices, ``maxp`` a multiple of
    ``pages_per_step`` (pad rows with any allocated page — masked);
    lengths: (B,) i32 valid tokens *including* the current one; window:
    (1,) i32 (0 = unlimited).  Returns (B, Hkv, rep, hdv) f32; rows with
    ``length <= 0`` return zeros.
    """
    policy = get_policy(policy_name)
    assert not policy.is_plain(), "paged kernel is for split policies"
    B, Hkv, rep2, hd = q.shape
    NP, ps, Hkv2, hd2 = k_pages.shape
    hdv = v_pages.shape[3]
    assert rep2 == rep and Hkv2 == Hkv and hd2 == hd, (q.shape, k_pages.shape)
    assert v_pages.shape[:3] == k_pages.shape[:3], (k_pages.shape,
                                                    v_pages.shape)
    G = pages_per_step
    maxp = block_tables.shape[1]
    assert block_tables.shape[0] == B and maxp % G == 0, (block_tables.shape,
                                                          G)
    assert paged_vmem_bytes(G, ps, rep, hd, hdv, policy) <= VMEM_BUDGET, \
        (G, ps, rep, hd, hdv, policy.name)
    n_steps = maxp // G
    grid = (B, Hkv, n_steps)

    def page_spec(j, width):
        return pl.BlockSpec(
            (1, ps, 1, width),
            lambda b, h, i, tbl, lens, win, j=j: (tbl[b, i * G + j], 0, h, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1, rep, hd),
                               lambda b, h, i, tbl, lens, win: (b, h, 0, 0))]
                 + [page_spec(j, hd) for j in range(G)]
                 + [page_spec(j, hdv) for j in range(G)],
        out_specs=pl.BlockSpec((1, 1, rep, hdv),
                               lambda b, h, i, tbl, lens, win: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((rep, 128), jnp.float32),    # running m
                        pltpu.VMEM((rep, 128), jnp.float32)]    # running l
                       + [pltpu.VMEM((rep, hdv), jnp.float32)
                          for _ in policy.groups],
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = _compiler_params(
            ("parallel", "parallel", "arbitrary"))
    kern = functools.partial(
        _paged_kernel, policy=policy, rep=rep, pages=G, n_steps=n_steps,
        softcap=softcap, sm_denom=sm_denom, upcast=interpret)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hdv), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(block_tables, lengths, window,
      q, *([k_pages] * G), *([v_pages] * G))


def tcec_paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                         policy: str = "tcec_bf16x6", window=0,
                         softcap: float | None = None,
                         pages_per_step: int | None = None,
                         interpret: bool | None = None) -> jax.Array:
    """Public entry: fused paged decode attention on model-layout operands.

    q: (B, H, hd) — one query token per sequence slot; k_pages/v_pages:
    (NP, ps, Hkv, hd[v]) page pools; block_tables: (B, maxp) i32;
    lengths: (B,) i32 valid tokens including the current one (the current
    token's K/V must already be written to its page).  GQA via
    ``H = rep * Hkv``; ``window`` may be a traced scalar (0 = unlimited).
    Returns (B, H, hdv) f32.
    """
    B, H, hd = q.shape
    NP, ps, Hkv, _ = k_pages.shape
    hdv = v_pages.shape[3]
    assert H % Hkv == 0, (H, Hkv)
    rep = H // Hkv
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    maxp = block_tables.shape[1]
    if pages_per_step is None:
        from . import tuning
        pages_per_step = tuning.get_paged_block(B, Hkv, rep, maxp, ps, hd,
                                                hdv, policy)
    G = max(1, min(int(pages_per_step), maxp))
    bt = jnp.asarray(block_tables, jnp.int32)
    pad = (-maxp) % G
    if pad:
        bt = jnp.pad(bt, ((0, 0), (0, pad)))
    win = jnp.asarray(0 if window is None else window, jnp.int32).reshape(1)
    qt = q.astype(jnp.float32).reshape(B, Hkv, rep, hd)
    out = tcec_paged_attention_pallas(
        qt, k_pages, v_pages, bt, jnp.asarray(lengths, jnp.int32), win,
        policy_name=policy, rep=rep, pages_per_step=G,
        softcap=(float(softcap) if softcap else None),
        sm_denom=float(np.sqrt(hd)), interpret=interpret)
    return out.reshape(B, H, hdv)
