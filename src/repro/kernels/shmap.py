"""Sharded TCEC: ``shard_map`` dispatch for the Pallas kernels under a mesh.

Before this module, every dispatch site declined the moment a GSPMD mesh
was installed (``parallel/ctx.py``): a bare ``pallas_call`` inside a
GSPMD program is replicated per device, so the exact configuration the
production posture cares about — sharded training and serving — silently
fell back to the XLA term expansion.  This module closes that gap: it
maps the framework's mesh conventions (``parallel/sharding.py``: batch on
the ``pod``/``data`` axes, heads / hidden / sequence on ``model``) onto
per-shard operand ``PartitionSpec``s and wraps each kernel call in
``jax.experimental.shard_map.shard_map``, so every device runs the fused
kernel on *its shard only* and GSPMD inserts at most a reshard on entry.

Three plan builders — :func:`matmul_plan`, :func:`attention_plan`,
:func:`paged_plan` — decide, from static shapes and the installed mesh,
which dims each mesh axis shards.  A plan is ``None`` when some axis of
size > 1 cannot be assigned to a dividing dim (or carries a name outside
the framework's ``pod``/``data``/``model`` convention); dispatch then
declines to the XLA fallback, whose collectives GSPMD already shards well
(the *unsupported-spec decline path* — tested).  Axes of size 1 never
block a plan, so a single-device mesh still routes through the wrapper
(tests exercise the full code path without a multi-device runtime).

Reduction-order guarantee (the part that must be pinned, not just made to
run — Khattak & Mikaitis, "Accurate Models of NVIDIA Tensor Cores", and
Valpey et al.'s SMT formalization both show split-term summation order
changes the error bound):

  * **M/N/batch/head/sequence sharding** splits only *independent* output
    rows/columns across devices.  Every scale-group fold happens locally
    and completely; per-shard results are **bit-identical** to the
    unsharded kernel on the same data.
  * **K sharding** splits the contraction.  Each device folds its local
    partial products smallest-first (the paper's Code-3 epilogue,
    unchanged), and only *then* does one f32 ``psum`` combine the
    per-device partial GEMMs.  The cross-device sum is therefore an f32
    RN reduction of f32 partials — the same associativity class as the
    kernel's own f32 K-grid accumulation (the paper's RZ-avoidance is
    preserved; no split term ever crosses the wire), so the error bound
    gains only the usual log₂(shards) f32 summation ULPs.  The order —
    local fold FIRST, f32 psum AFTER — is asserted by tests
    (``tests/test_shmap.py``) and documented in ``docs/parallel.md``.

Autotuning under a plan measures the **local tile**, not the global
shape: block lookups go to the ``backend/shmap/...`` cache namespace
keyed by the per-shard problem (``kernels/tuning.py``), since the tile
the kernel actually runs is the shard.

The :func:`counters` view increments once per wrapped dispatch at trace
time (the ``kernels/shmap/calls`` registry counter in
:mod:`repro.obs.metrics`) — the acceptance hook tests use to assert
that a mesh-installed program really routed through the kernels.
"""
from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import numerics
from repro.obs import metrics as _metrics

# Cache namespace for per-shard tuning keys: ``backend/shmap/...``.
NAMESPACE = "shmap"

#: the wrapped kernels (label values of ``kernels/shmap/calls``)
KERNELS = ("matmul", "attention", "paged")


def _bump(kernel: str):
    _metrics.counter("kernels/shmap/calls").inc(kernel=kernel)


def counters() -> dict[str, int]:
    """Trace-time sharded-dispatch counts, ``{kernel: calls}`` (zeroes
    included).  Backed by the ``kernels/shmap/calls`` registry counter,
    so ``repro.obs.snapshot()`` carries the same numbers."""
    c = _metrics.counter("kernels/shmap/calls")
    return {k: int(c.value(kernel=k)) for k in KERNELS}


def reset_counters():
    _metrics.counter("kernels/shmap/calls").reset()


class _CallsView(Mapping):
    """Read-only live view backing the deprecated :data:`CALLS` dict."""

    def __getitem__(self, key):
        if key not in KERNELS:
            raise KeyError(key)
        return counters()[key]

    def __iter__(self):
        return iter(KERNELS)

    def __len__(self):
        return len(KERNELS)

    def __repr__(self):
        return repr(counters())


def __getattr__(name):
    if name == "CALLS":
        numerics._deprecated("repro.kernels.shmap.CALLS",
                             "repro.kernels.shmap.counters()")
        return _CallsView()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def reset_calls():
    """Deprecated: use :func:`reset_counters`."""
    numerics._deprecated("repro.kernels.shmap.reset_calls()",
                         "repro.kernels.shmap.reset_counters()")
    reset_counters()


def _cfg(cfg) -> numerics.NumericsConfig:
    return cfg if cfg is not None else numerics.active()


def _interpret(cfg) -> bool:
    if cfg.interpret is not None:
        return cfg.interpret
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------- plans
#
# The framework's axis convention (parallel/sharding.py): ``pod``/``data``
# are the data-parallel axes, ``model`` is the tensor-parallel axis.  A
# plan assigns every size->1 mesh axis to a dim it divides; unknown axis
# names of size > 1 make the spec unsupported.

def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 1


def _dp_size(mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= int(mesh.shape[a])
    return n


def _known_axes_only(mesh) -> bool:
    return all(a in ("pod", "data", "model") or int(mesh.shape[a]) == 1
               for a in mesh.axis_names)


@dataclass(frozen=True)
class MatmulPlan:
    """Per-shard operand specs for one canonical ``(B?, M, K) @ (B?, K, N)``.

    ``psum_axes`` is non-empty iff the contraction (K) is sharded: the body
    then f32-``psum``s the *locally folded* partial GEMM across those axes
    (see the module docstring's reduction-order guarantee).  ``local`` is
    the per-shard ``(B, M, N, K)`` the autotuner keys on.
    """
    a_spec: P
    b_spec: P
    out_spec: P
    psum_axes: tuple[str, ...]
    local: tuple[int, int, int, int]
    sharded_dim: str                 # "batch" | "M" | "N" | "K" | "none"


def matmul_plan(a_shape, b_shape, mesh) -> MatmulPlan | None:
    """Assign mesh axes to the dims of a canonical GEMM, or None.

    Data-parallel axes take the batch dim (3-D operands) or M (2-D).  The
    ``model`` axis prefers N (column parallel — matches the up-projection
    weight sharding), then K (row parallel: local fold + f32 psum — the
    down-projection), then M (row-sharded activations).  Any size->1 axis
    left unassignable makes the spec unsupported (return None).
    """
    if not _known_axes_only(mesh):
        return None
    batched = len(a_shape) == 3
    B = a_shape[0] if batched else 1
    M, K = a_shape[-2], a_shape[-1]
    N = b_shape[-1]
    dp = _dp_axes(mesh)
    dsize = _dp_size(mesh)
    msize = _axis_size(mesh, "model")

    Bl, Ml, Nl, Kl = B, M, N, K
    a_dims = [None] * len(a_shape)
    b_dims = [None] * len(b_shape)
    o_dims = [None] * len(a_shape)

    # data-parallel axes -> batch (batched) or M (2-D)
    m_taken = False
    if dsize > 1:
        if batched and B % dsize == 0:
            a_dims[0] = b_dims[0] = o_dims[0] = dp if len(dp) > 1 else dp[0]
            Bl = B // dsize
        elif M % dsize == 0:
            a_dims[-2] = o_dims[-2] = dp if len(dp) > 1 else dp[0]
            Ml = M // dsize
            m_taken = True
        else:
            return None

    psum: tuple[str, ...] = ()
    sharded = "none"
    if msize > 1:
        if N % msize == 0:
            b_dims[-1] = o_dims[-1] = "model"
            Nl = N // msize
            sharded = "N"
        elif K % msize == 0:
            a_dims[-1] = b_dims[-2] = "model"
            Kl = K // msize
            psum = ("model",)
            sharded = "K"
        elif M % msize == 0 and not m_taken:
            a_dims[-2] = o_dims[-2] = "model"
            Ml = M // msize
            sharded = "M"
        else:
            return None
    elif dsize > 1:
        sharded = "batch" if (batched and Bl != B) else "M"

    return MatmulPlan(P(*a_dims), P(*b_dims), P(*o_dims), psum,
                      (Bl, Ml, Nl, Kl), sharded)


@dataclass(frozen=True)
class AttentionPlan:
    """Per-shard specs for model-layout attention operands.

    ``mode`` is ``"heads"`` (KV-head groups on ``model`` — the TP layout
    matching the wq/wk/wv weight sharding) or ``"qseq"`` (query-sequence
    on ``model`` with K/V replicated — context parallelism; the causal /
    window masks stay correct because the *global* position vectors are
    sharded alongside q, so each shard sees its true offsets).  ``local``
    is the per-shard ``(B, Hkv, S, T)`` the autotuner keys on.
    """
    q_spec: P
    k_spec: P
    v_spec: P
    qp_spec: P
    kp_spec: P
    out_spec: P
    local: tuple[int, int, int, int]
    mode: str


def attention_plan(q_shape, k_shape, mesh) -> AttentionPlan | None:
    """q ``(B, S, H, hd)``, k ``(B, T, Hkv, hd)`` -> plan or None.

    ``model`` prefers head sharding (requires ``Hkv % msize == 0`` so the
    contiguous H chunks align with whole GQA groups — q reshapes to
    ``(B, S, Hkv, rep, hd)`` KV-head-major), else q-sequence sharding
    (``S % msize == 0``).  Data-parallel axes take the batch.
    """
    if not _known_axes_only(mesh):
        return None
    B, S, H, _ = q_shape
    T, Hkv = k_shape[1], k_shape[2]
    dp = _dp_axes(mesh)
    dsize = _dp_size(mesh)
    msize = _axis_size(mesh, "model")

    bdim = None
    Bl = B
    if dsize > 1:
        if B % dsize != 0:
            return None
        bdim = dp if len(dp) > 1 else dp[0]
        Bl = B // dsize

    Hkvl, Sl = Hkv, S
    if msize > 1 and Hkv % msize == 0:
        mode = "heads"
        Hkvl = Hkv // msize
        q_spec = P(bdim, None, "model", None)
        k_spec = v_spec = P(bdim, None, "model", None)
        qp_spec = kp_spec = P(bdim, None)
        out_spec = P(bdim, None, "model", None)
    elif msize > 1 and S % msize == 0:
        mode = "qseq"
        Sl = S // msize
        q_spec = P(bdim, "model", None, None)
        k_spec = v_spec = P(bdim, None, None, None)
        qp_spec = P(bdim, "model")
        kp_spec = P(bdim, None)
        out_spec = P(bdim, "model", None, None)
    elif msize > 1:
        return None
    else:
        mode = "heads"
        q_spec = k_spec = v_spec = P(bdim, None, None, None)
        qp_spec = kp_spec = P(bdim, None)
        out_spec = P(bdim, None, None, None)
    return AttentionPlan(q_spec, k_spec, v_spec, qp_spec, kp_spec, out_spec,
                         (Bl, Hkvl, Sl, T), mode)


@dataclass(frozen=True)
class PagedPlan:
    """Per-shard specs for paged decode attention.

    The page pools shard their KV-head dim on ``model`` (each device owns
    its heads' slices of *every* page); block tables and lengths stay
    device-local — replicated over ``model``, batch-sharded over the
    data-parallel axes with the query.  ``local`` is the per-shard
    ``(B, Hkv)`` the pages-per-step autotuner keys on.
    """
    q_spec: P
    pool_spec: P
    bt_spec: P
    len_spec: P
    out_spec: P
    local: tuple[int, int]


def paged_plan(q_shape, pool_shape, mesh) -> PagedPlan | None:
    """q ``(B, H, hd)``, pools ``(NP, ps, Hkv, hd)`` -> plan or None."""
    if not _known_axes_only(mesh):
        return None
    B, H, _ = q_shape
    Hkv = pool_shape[2]
    dp = _dp_axes(mesh)
    dsize = _dp_size(mesh)
    msize = _axis_size(mesh, "model")

    bdim = None
    Bl = B
    if dsize > 1:
        if B % dsize != 0:
            return None
        bdim = dp if len(dp) > 1 else dp[0]
        Bl = B // dsize

    Hkvl = Hkv
    hdim = None
    if msize > 1:
        if Hkv % msize != 0:
            return None
        hdim = "model"
        Hkvl = Hkv // msize
    return PagedPlan(
        q_spec=P(bdim, hdim, None),
        pool_spec=P(None, None, hdim, None),
        bt_spec=P(bdim, None),
        len_spec=P(bdim),
        out_spec=P(bdim, hdim, None),
        local=(Bl, Hkvl))


# -------------------------------------------------------------- wrappers

def sharded_matmul(a, b, *, policy: str, mesh, cfg=None,
                   plan: MatmulPlan | None = None) -> jax.Array:
    """Run the fused TCEC GEMM per shard under ``mesh``.

    Operands are the canonical ``(B?, M, K) @ (B?, K, N)`` the dispatch
    layer produces.  K-sharded plans fold each shard's scale groups
    locally (the paper's smallest-first epilogue, untouched) and then
    ``psum`` the f32 partial products — see the module docstring for why
    that order preserves the error bound.
    """
    from . import ops, tuning
    cfg = _cfg(cfg)
    if plan is None:
        plan = matmul_plan(a.shape, b.shape, mesh)
    assert plan is not None, (a.shape, b.shape, dict(mesh.shape))
    Bl, Ml, Nl, Kl = plan.local
    block = cfg.block
    if block is None:
        block = tuning.get_block(Ml, Nl, Kl, policy, batch=Bl, cfg=cfg,
                                 namespace=NAMESPACE)
    interpret = _interpret(cfg)

    def body(x, y):
        out = ops.tcec_matmul(x, y, policy=policy, block=block,
                              interpret=interpret, cfg=cfg)
        if plan.psum_axes:
            # f32 RN sum of fully-folded f32 partials — AFTER the local
            # smallest-first group fold, never across split terms
            out = jax.lax.psum(out, plan.psum_axes)
        return out

    _bump("matmul")
    return shard_map(body, mesh=mesh, in_specs=(plan.a_spec, plan.b_spec),
                     out_specs=plan.out_spec, check_rep=False)(a, b)


def _pos_2d(pos, B, n):
    """Global (B, n) i32 positions — materialized BEFORE shard_map so a
    q-sequence shard sees its true global offsets, not a local arange."""
    if pos is None:
        pos = jnp.arange(n, dtype=jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None], (B, n))
    return pos


def sharded_attention(q, k, v, q_pos=None, k_pos=None, *, policy: str,
                      causal: bool = True, window=0,
                      softcap: float | None = None, mesh, cfg=None,
                      plan: AttentionPlan | None = None) -> jax.Array:
    """Run the fused TCEC flash-attention kernel per shard under ``mesh``.

    Model-layout operands (q ``(B, S, H, hd)``, k/v ``(B, T, Hkv,
    hd[v])``).  Head sharding gives each device whole GQA groups (K/V
    never replicated across ``model``); q-sequence sharding replicates
    K/V and shards the query rows, with the causal/window masks offset by
    the shard's global position via the sharded position vectors.  Either
    way the softmax and every scale-group fold complete locally, so each
    shard is bit-identical to the unsharded kernel on the same rows.
    """
    from . import tuning
    cfg = _cfg(cfg)
    if plan is None:
        plan = attention_plan(q.shape, k.shape, mesh)
    assert plan is not None, (q.shape, k.shape, dict(mesh.shape))
    B, S, H, hd = q.shape
    T, Hkv, hdv = k.shape[1], k.shape[2], v.shape[3]
    Bl, Hkvl, Sl, Tl = plan.local
    block = cfg.attn_block
    if block is None:
        block = tuning.get_attention_block(Bl, Hkvl, H // Hkv, Sl, Tl, hd,
                                           hdv, policy, causal=causal,
                                           cfg=cfg, namespace=NAMESPACE)
    interpret = _interpret(cfg)
    qp = _pos_2d(q_pos, B, S)
    kp = _pos_2d(k_pos, B, T)
    win = jnp.asarray(0 if window is None else window, jnp.int32)

    def body(qs, ks, vs, qps, kps, w):
        from .tcec_attention import tcec_attention
        return tcec_attention(qs, ks, vs, qps, kps, policy=policy,
                              causal=causal, window=w, softcap=softcap,
                              block=block, interpret=interpret)

    _bump("attention")
    return shard_map(
        body, mesh=mesh,
        in_specs=(plan.q_spec, plan.k_spec, plan.v_spec, plan.qp_spec,
                  plan.kp_spec, P()),
        out_specs=plan.out_spec, check_rep=False)(q, k, v, qp, kp, win)


def sharded_paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                            policy: str, window=0,
                            softcap: float | None = None, mesh, cfg=None,
                            plan: PagedPlan | None = None) -> jax.Array:
    """Run the fused paged decode-attention kernel per shard under ``mesh``.

    The pools shard on the KV-head dim (``model``); block tables and
    lengths stay device-local (replicated over ``model``), so the page
    gather on each device reads its own pool shard with the *same* table —
    no cross-device page traffic.  Batch shards over the data axes.
    """
    from . import tuning
    cfg = _cfg(cfg)
    if plan is None:
        plan = paged_plan(q.shape, k_pages.shape, mesh)
    assert plan is not None, (q.shape, k_pages.shape, dict(mesh.shape))
    B, H, hd = q.shape
    NP, ps, Hkv, _ = k_pages.shape
    hdv = v_pages.shape[3]
    Bl, Hkvl = plan.local
    maxp = block_tables.shape[1]
    g = cfg.paged_block
    if g is None:
        g = tuning.get_paged_block(Bl, Hkvl, H // Hkv, maxp, ps, hd, hdv,
                                   policy, cfg=cfg, namespace=NAMESPACE)
    interpret = _interpret(cfg)
    bt = jnp.asarray(block_tables, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    # the (possibly traced) window rides as an explicit replicated operand:
    # shard_map bodies must not close over outer-trace values
    win = jnp.asarray(0 if window is None else window, jnp.int32)

    def body(qs, kps, vps, bts, lns, w):
        from .tcec_paged_attention import tcec_paged_attention
        return tcec_paged_attention(qs, kps, vps, bts, lns, policy=policy,
                                    window=w, softcap=softcap,
                                    pages_per_step=g, interpret=interpret)

    _bump("paged")
    return shard_map(
        body, mesh=mesh,
        in_specs=(plan.q_spec, plan.pool_spec, plan.pool_spec, plan.bt_spec,
                  plan.len_spec, P()),
        out_specs=plan.out_spec, check_rep=False)(
            q, k_pages, v_pages, bt, lens, win)
