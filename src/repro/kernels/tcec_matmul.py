"""TCEC matmul Pallas kernel — the paper's CUTLASS integration, TPU-native.

One fused kernel computes an FP32-accurate GEMM on the bf16 MXU:

  * f32 A/B tiles stream HBM -> VMEM exactly once (same traffic as SGEMM —
    the paper's "no extra memory footprint" property: splits are never
    materialized to HBM, they are computed in-register per tile, mirroring
    the paper's "compute Eq (19)-(22) on the registers" CUTLASS change);
  * the split products run as 3 (``tcec_bf16x3``) or 6 (``tcec_bf16x6``)
    bf16 MXU passes per tile with f32 outputs;
  * accumulation across the K grid happens in **f32 VMEM scratch** outside
    the MXU accumulation chain — the paper's RZ-avoidance (Fig. 6) — with
    one scratch accumulator per scale group (Code 3's frag_c / frag_dc);
  * the scaled epilogue folds correction groups smallest-first on the last
    K step (Code 3's ``frag_c.x[i] += frag_dc.x[i]/2048``) and can
    optionally fold a bias add, an output scale, and an activation into
    the same VMEM-resident pass (model layers use this to fuse their
    linear-layer epilogues — no extra HBM round trip for ``act(xW + b)``).

The kernel runs on a 3-D grid ``(M/bm, N/bn, K/bk)`` for 2-D operands and a
4-D grid ``(B, M/bm, N/bn, K/bk)`` for batched operands (``policy_bmm`` /
attention-shaped contractions), with the batch dimension blocked at 1.

Block shapes are BlockSpec parameters; MXU-aligned multiples of 128 are
enforced by the ops.py wrapper, and the VMEM working set is checked against
the per-core budget (the analogue of the paper's shared-memory-capacity
filter in their CUTLASS parameter sweep).  Block *selection* lives in
``kernels/tuning.py`` (measured autotuner) and ``kernels/dispatch.py``
routes framework contractions here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policy import PrecisionPolicy, get_policy

VMEM_BUDGET = 64 * 1024 * 1024  # v5e VMEM ~128MB/core; leave headroom

# Activations the fused epilogue supports. These are the exact jnp/jax.nn
# callables the reference (unfused) model path uses, so fusing an epilogue
# never changes numerics — only where it runs.
EPILOGUE_ACTIVATIONS = {
    None: lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def _split_tile(x, n_splits: int, scale_bits: int):
    """In-register split of an f32 tile into bf16 terms (Eqs. 19-22)."""
    scale = jnp.float32(2.0 ** scale_bits)
    parts = []
    r = x
    for i in range(n_splits):
        a = r.astype(jnp.bfloat16)
        parts.append(a)
        if i + 1 < n_splits:
            r = (r - a.astype(jnp.float32)) * scale
    return parts


def _kernel(*refs, policy: PrecisionPolicy, k_steps: int, k_axis: int,
            batched: bool, has_bias: bool, activation: str | None,
            out_scale: float, upcast: bool):
    if has_bias:
        a_ref, b_ref, bias_ref, o_ref, *accs = refs
    else:
        a_ref, b_ref, o_ref, *accs = refs
        bias_ref = None
    k = pl.program_id(k_axis)
    groups = policy.groups

    @pl.when(k == 0)
    def _init():
        for acc in accs:
            acc[...] = jnp.zeros_like(acc)

    a = a_ref[0] if batched else a_ref[...]   # (bm, bk) f32
    b = b_ref[0] if batched else b_ref[...]   # (bk, bn) f32
    sa = _split_tile(a, policy.n_splits, policy.scale_bits)
    sb = _split_tile(b, policy.n_splits, policy.scale_bits)
    if upcast:
        # interpret mode: XLA-CPU lacks bf16 DotThunks for some shapes.
        # bf16 -> f32 is exact and two bf16-valued f32 factors multiply
        # exactly in f32 (8+8 <= 24 mantissa bits), so this is bit-identical
        # to the MXU contract (exact products, f32 RN accumulation).
        sa = [t.astype(jnp.float32) for t in sa]
        sb = [t.astype(jnp.float32) for t in sb]

    for gi, g in enumerate(groups):
        part = None
        for (i, j) in policy.keep:
            if i + j != g:
                continue
            t = jnp.dot(sa[i], sb[j], preferred_element_type=jnp.float32)
            part = t if part is None else part + t
        # f32 VMEM accumulate — outside the MXU chain (RN adds, Fig. 6)
        accs[gi][...] += part

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = accs[len(groups) - 1][...]
        inv = jnp.float32(2.0 ** (-policy.scale_bits))
        for gi in range(len(groups) - 2, -1, -1):
            out = accs[gi][...] + out * inv
        # fused scaled epilogue: scale -> bias -> activation, all in VMEM
        if out_scale != 1.0:
            out = out * jnp.float32(out_scale)
        if bias_ref is not None:
            out = out + bias_ref[...]          # (1, bn) broadcasts over bm
        out = EPILOGUE_ACTIVATIONS[activation](out)
        if batched:
            o_ref[0] = out
        else:
            o_ref[...] = out


def vmem_bytes(block: tuple[int, int, int], policy: PrecisionPolicy,
               has_bias: bool = False) -> int:
    """VMEM working set of one grid step (the shared-memory-capacity filter)."""
    bm, bn, bk = block
    groups = len(policy.groups)
    tiles = (bm * bk + bk * bn) * 4                      # f32 A/B tiles
    splits = (bm * bk + bk * bn) * 2 * policy.n_splits   # bf16 split terms
    accs = groups * bm * bn * 4                          # f32 accumulators
    out = bm * bn * 4
    bias = bn * 4 if has_bias else 0
    return tiles + splits + accs + out + bias


@functools.partial(jax.jit, static_argnames=("policy_name", "block",
                                             "interpret", "activation",
                                             "out_scale"))
def tcec_matmul_pallas(a: jax.Array, b: jax.Array, bias: jax.Array | None = None,
                       *, policy_name: str,
                       block: tuple[int, int, int] = (128, 128, 128),
                       interpret: bool = False, activation: str | None = None,
                       out_scale: float = 1.0) -> jax.Array:
    """Fused TCEC GEMM on pre-padded operands.

    2-D: ``(M, K) @ (K, N) -> (M, N)`` f32; batched: ``(B, M, K) @ (B, K, N)
    -> (B, M, N)``.  M/N/K must be multiples of ``block``; ``bias`` (if any)
    must be pre-shaped ``(1, N)``.  The optional epilogue computes
    ``act(out * out_scale + bias)`` inside the kernel's final K step.
    """
    policy = get_policy(policy_name)
    assert not policy.is_plain(), "pallas kernel is for split policies"
    assert activation in EPILOGUE_ACTIVATIONS, activation
    batched = a.ndim == 3
    if batched:
        B, M, K = a.shape
        B2, K2, N = b.shape
        assert B == B2, (a.shape, b.shape)
    else:
        M, K = a.shape
        K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = block
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, block)
    has_bias = bias is not None
    assert vmem_bytes(block, policy, has_bias) <= VMEM_BUDGET, \
        (block, policy.name)
    if has_bias:
        assert bias.shape == (1, N), bias.shape
    groups = policy.groups
    k_steps = K // bk

    if batched:
        grid = (B, M // bm, N // bn, k_steps)
        in_specs = [
            pl.BlockSpec((1, bm, bk), lambda p, i, j, k: (p, i, k)),
            pl.BlockSpec((1, bk, bn), lambda p, i, j, k: (p, k, j)),
        ]
        if has_bias:
            in_specs.append(pl.BlockSpec((1, bn), lambda p, i, j, k: (0, j)))
        out_specs = pl.BlockSpec((1, bm, bn), lambda p, i, j, k: (p, i, j))
        out_shape = jax.ShapeDtypeStruct((B, M, N), jnp.float32)
        semantics = ("parallel", "parallel", "parallel", "arbitrary")
    else:
        grid = (M // bm, N // bn, k_steps)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ]
        if has_bias:
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        out_specs = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
        out_shape = jax.ShapeDtypeStruct((M, N), jnp.float32)
        semantics = ("parallel", "parallel", "arbitrary")

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=semantics)

    kern = functools.partial(
        _kernel, policy=policy, k_steps=k_steps, k_axis=len(grid) - 1,
        batched=batched, has_bias=has_bias, activation=activation,
        out_scale=out_scale, upcast=interpret)
    operands = (a, b, bias) if has_bias else (a, b)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32) for _ in groups],
        interpret=interpret,
        **kwargs,
    )(*operands)
