"""TCEC matmul Pallas kernel — the paper's CUTLASS integration, TPU-native.

One fused kernel computes an FP32-accurate GEMM on the bf16 MXU:

  * f32 A/B tiles stream HBM -> VMEM exactly once (same traffic as SGEMM —
    the paper's "no extra memory footprint" property: splits are never
    materialized to HBM, they are computed in-register per tile, mirroring
    the paper's "compute Eq (19)-(22) on the registers" CUTLASS change);
  * the split products run as 3 (``tcec_bf16x3``) or 6 (``tcec_bf16x6``)
    bf16 MXU passes per tile with f32 outputs;
  * accumulation across the K grid happens in **f32 VMEM scratch** outside
    the MXU accumulation chain — the paper's RZ-avoidance (Fig. 6) — with
    one scratch accumulator per scale group (Code 3's frag_c / frag_dc);
  * the scaled epilogue folds correction groups smallest-first on the last
    K step (Code 3's ``frag_c.x[i] += frag_dc.x[i]/2048``).

Block shapes are BlockSpec parameters; MXU-aligned multiples of 128 are
enforced by the ops.py wrapper, and the VMEM working set is checked against
the per-core budget (the analogue of the paper's shared-memory-capacity
filter in their CUTLASS parameter sweep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policy import PrecisionPolicy, get_policy

VMEM_BUDGET = 64 * 1024 * 1024  # v5e VMEM ~128MB/core; leave headroom


def _split_tile(x, n_splits: int, scale_bits: int):
    """In-register split of an f32 tile into bf16 terms (Eqs. 19-22)."""
    scale = jnp.float32(2.0 ** scale_bits)
    parts = []
    r = x
    for i in range(n_splits):
        a = r.astype(jnp.bfloat16)
        parts.append(a)
        if i + 1 < n_splits:
            r = (r - a.astype(jnp.float32)) * scale
    return parts


def _kernel(a_ref, b_ref, o_ref, *accs, policy: PrecisionPolicy, k_steps: int):
    k = pl.program_id(2)
    groups = sorted({i + j for (i, j) in policy.keep})

    @pl.when(k == 0)
    def _init():
        for acc in accs:
            acc[...] = jnp.zeros_like(acc)

    a = a_ref[...]  # (bm, bk) f32
    b = b_ref[...]  # (bk, bn) f32
    sa = _split_tile(a, policy.n_splits, policy.scale_bits)
    sb = _split_tile(b, policy.n_splits, policy.scale_bits)

    for gi, g in enumerate(groups):
        part = None
        for (i, j) in policy.keep:
            if i + j != g:
                continue
            t = jnp.dot(sa[i], sb[j], preferred_element_type=jnp.float32)
            part = t if part is None else part + t
        # f32 VMEM accumulate — outside the MXU chain (RN adds, Fig. 6)
        accs[gi][...] += part

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = accs[len(groups) - 1][...]
        inv = jnp.float32(2.0 ** (-policy.scale_bits))
        for gi in range(len(groups) - 2, -1, -1):
            out = accs[gi][...] + out * inv
        o_ref[...] = out


def vmem_bytes(block: tuple[int, int, int], policy: PrecisionPolicy) -> int:
    """VMEM working set of one grid step (the shared-memory-capacity filter)."""
    bm, bn, bk = block
    groups = len({i + j for (i, j) in policy.keep})
    tiles = (bm * bk + bk * bn) * 4                      # f32 A/B tiles
    splits = (bm * bk + bk * bn) * 2 * policy.n_splits   # bf16 split terms
    accs = groups * bm * bn * 4                          # f32 accumulators
    out = bm * bn * 4
    return tiles + splits + accs + out


@functools.partial(jax.jit, static_argnames=("policy_name", "block", "interpret"))
def tcec_matmul_pallas(a: jax.Array, b: jax.Array, *, policy_name: str,
                       block: tuple[int, int, int] = (128, 128, 128),
                       interpret: bool = False) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N) f32; dims must be multiples of ``block``."""
    policy = get_policy(policy_name)
    assert not policy.is_plain(), "pallas kernel is for split policies"
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = block
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, block)
    assert vmem_bytes(block, policy) <= VMEM_BUDGET, (block, policy.name)
    grid = (M // bm, N // bn, K // bk)
    groups = sorted({i + j for (i, j) in policy.keep})

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_kernel, policy=policy, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32) for _ in groups],
        interpret=interpret,
        **kwargs,
    )(a, b)
