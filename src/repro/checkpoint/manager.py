"""Sharded checkpointing with atomic commits, keep-k retention, integrity
hashes, resume, and elastic re-sharding onto a different mesh.

Layout:  <dir>/step_<n>/
           manifest.json       (step, leaf paths, shapes, dtypes, sha256s)
           <leaf-hash>.npy     (one file per pytree leaf, host-gathered)

Atomicity: written to ``step_<n>.tmp`` then os.rename'd — a crashed writer
never produces a loadable-but-corrupt checkpoint (restart-safety). On real
multi-host TPU jobs each host writes its address-able shards; here the
single-host path gathers to host numpy (the manifest format is identical)."""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _leaf_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        out.append(("/".join(parts), leaf))
    return out


def _fname(path: str) -> str:
    return hashlib.sha1(path.encode()).hexdigest()[:16] + ".npy"


def save(ckpt_dir: str, step: int, tree) -> str:
    """Atomically save a pytree checkpoint. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for path, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind not in "fiub":
            # ml_dtypes (bfloat16 / fp8): npy can't round-trip them — store
            # the raw bits under a same-width integer view
            width = arr.dtype.itemsize
            arr = arr.view({1: np.uint8, 2: np.uint16}[width])
        fn = _fname(path)
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][path] = {
            "file": fn, "shape": list(arr.shape), "dtype": dtype_name,
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None,
            verify: bool = True):
    """Restore into the structure of ``like_tree``; optionally re-shard onto
    a (possibly different) mesh — the elastic-restart path: a checkpoint
    written on N devices loads onto any M-device mesh whose axis sizes
    divide the array dims."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = _leaf_paths(like_tree)
    shard_flat = (_leaf_paths(shardings) if shardings is not None
                  else [(p, None) for p, _ in flat])
    out = []
    for (path, leaf), (_, shd) in zip(flat, shard_flat):
        meta = manifest["leaves"][path]
        arr = np.load(os.path.join(d, meta["file"]))
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            if h != meta["sha256"]:
                raise IOError(f"checkpoint corruption at {path}")
        if str(arr.dtype) != meta["dtype"]:   # raw-bits integer view
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        assert list(arr.shape) == list(leaf.shape), (path, arr.shape,
                                                     leaf.shape)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    tdef = jax.tree_util.tree_structure(like_tree)
    return jax.tree_util.tree_unflatten(tdef, out)


def retain(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
