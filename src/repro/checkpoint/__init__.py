from . import manager
