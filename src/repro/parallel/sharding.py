"""Sharding rules: parameter/activation/cache PartitionSpecs per family.

Logical layout on the production mesh (pod, data, model):
  * batch          -> (pod, data)        [DP across pods + within pod]
  * attention heads / mlp hidden / vocab / experts -> model   [TP / EP]
  * fsdp_tp mode   -> large params additionally sharded on data [ZeRO-3]
  * KV caches      -> batch on (pod,data) when divisible; head_dim (always a
    multiple of 16 in the zoo) on model, so decode works for kv_heads < 16.

Rules are path-regex -> per-dim templates, matched against the flattened
parameter path (MaxText-style logical rules, but on paths). If no "M" dim
of a matched template divides the model-axis size, the "model" axis falls
back to the last divisible dim (e.g. GQA wk with 8 kv heads on a 16-way
axis shards head_dim instead; non-256-multiple vocabs are padded upstream).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def data_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


# path-regex -> spec template ("M" = want model axis here; None = replicated).
# Templates are right-padded with None; first match wins.
_RULES: list[tuple[str, tuple | None]] = [
    (r"embed$", ("M", None)),
    (r"unembed$", (None, "M")),
    # attention ------------------------------------------------------------
    (r"(attn|xattn)/wq$", (None, "M", None)),
    (r"(attn|xattn)/w[kv]$", (None, "M", None)),
    (r"(attn|xattn)/wo$", ("M", None, None)),
    (r"(attn|xattn)/b[qkv]$", None),
    (r"(attn|xattn)/(q_norm|k_norm)$", None),
    # MLA -------------------------------------------------------------------
    (r"attn/w_dq$", None),
    (r"attn/w_uq$", (None, "M", None)),
    (r"attn/w_dkv$", None),
    (r"attn/w_u[kv]$", (None, "M", None)),
    (r"attn/w_kr$", None),
    # dense MLP ---------------------------------------------------------------
    (r"(mlp|shared)/w_gate$", (None, "M")),
    (r"(mlp|shared)/w_up$", (None, "M")),
    (r"(mlp|shared)/w_down$", ("M", None)),
    # MoE experts (EP on model) ---------------------------------------------
    (r"moe/router$", None),
    (r"moe/w_(gate|up|down)$", ("M", None, None)),
    # SSD ----------------------------------------------------------------------
    (r"ssd/w[zx]$", (None, "M")),
    (r"ssd/w(b|c|dt)$", None),
    (r"ssd/conv_x$", (None, "M")),
    (r"ssd/conv_bias_x$", ("M",)),
    (r"ssd/(conv_b|conv_c|conv_bias_[bc])$", None),
    (r"ssd/(A_log|D_skip|dt_bias)$", None),
    (r"ssd/norm$", ("M",)),
    (r"ssd/w_out$", ("M", None)),
    # hybrid / misc projections ------------------------------------------------
    (r"(mtp_proj|w_cat)$", ("M", None)),
    (r"shared/w_out$", ("M", None)),
    (r"frontend_proj$", ("M", None)),
    (r"projector/w1$", (None, "M")),
    (r"projector/w2$", ("M", None)),
    (r".*", None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path: str, shape, mesh: Mesh, cfg, stacked: bool):
    msize = model_size(mesh)
    off = 1 if stacked else 0
    body = shape[off:]
    for pat, tpl in _RULES:
        if not re.search(pat, path):
            continue
        dims: list = [None] * len(body)
        if tpl is not None:
            tplp = tuple(tpl) + (None,) * (len(body) - len(tpl))
            placed = False
            for d, t in enumerate(tplp[:len(body)]):
                if t == "M" and body[d] % msize == 0 and not placed:
                    dims[d] = "model"
                    placed = True
            if not placed and any(t == "M" for t in tplp):
                # fallback: last divisible dim gets the model axis
                for d in range(len(body) - 1, -1, -1):
                    if body[d] % msize == 0:
                        dims[d] = "model"
                        break
        dims = _apply_fsdp(path, body, dims, mesh, cfg)
        if stacked:
            dims = [None] + dims
        return P(*dims)
    return P()


_FSDP_MIN_SIZE = 1 << 22  # 4M elements


def _apply_fsdp(path, body, dims, mesh, cfg):
    """fsdp_tp: shard the largest still-replicated dim of big params over
    the data axes (ZeRO-3; across pods too when the pod axis exists)."""
    if getattr(cfg, "shard_mode", "tp") != "fsdp_tp":
        return dims
    if int(np.prod(body)) < _FSDP_MIN_SIZE:
        return dims
    for axes in (dp_axes(mesh), ("data",)):
        fsdp_size = int(np.prod([mesh.shape[a] for a in axes]))
        cand = [(body[i], i) for i in range(len(body))
                if dims[i] is None and body[i] % fsdp_size == 0]
        if cand:
            _, idx = max(cand)
            dims = list(dims)
            dims[idx] = tuple(axes) if len(axes) > 1 else axes[0]
            return dims
    return dims


def param_specs(param_shapes, mesh: Mesh, cfg):
    """PartitionSpec tree for a parameter pytree (ShapeDtypeStructs or
    arrays). Layer-stacked leaves (under *blocks*) get their leading stack
    dim replicated."""
    msize = model_size(mesh)
    dsize = mesh.shape.get("data", 1)

    def fn(path, leaf):
        ps = _path_str(path)
        if getattr(cfg, "dp_over_model", False):
            return P()        # small model: replicate, model axis = extra DP
        stacked = "blocks" in ps
        if getattr(cfg, "ep_mode", "1d") == "2d" and \
                re.search(r"moe/w_(gate|up|down)$", ps):
            off = 1 if stacked else 0
            E = leaf.shape[off]
            if E % (msize * dsize) == 0:
                dims = [None] * len(leaf.shape)
                dims[off] = ("model", "data")   # 1 expert per chip: no
                return P(*dims)                  # FSDP weight gathers
        return _spec_for(ps, leaf.shape, mesh, cfg, stacked)
    return jax.tree_util.tree_map_with_path(fn, param_shapes)


def batch_axes(cfg, mesh: Mesh):
    axes = dp_axes(mesh)
    if getattr(cfg, "dp_over_model", False):
        axes = axes + ("model",)
    return axes


def batch_specs(cfg, mesh: Mesh, batch_shapes):
    """Batch inputs: shard the leading (global-batch) dim on (pod, data)
    — plus model when the config runs DP-over-model."""
    dp = batch_axes(cfg, mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dp]))

    def fn(leaf):
        if leaf.shape and leaf.shape[0] % dsize == 0:
            return P(dp)
        return P()
    return jax.tree.map(fn, batch_shapes)


def cache_specs(cfg, mesh: Mesh, cache_shapes, batch: int, max_len: int):
    """KV/SSM cache specs (see module docstring)."""
    dp = dp_axes(mesh)
    dsize = data_size(mesh)
    msize = model_size(mesh)

    def fn(leaf):
        dims = [None] * len(leaf.shape)
        for i in range(1, len(leaf.shape)):
            if leaf.shape[i] == batch and batch % dsize == 0:
                dims[i] = dp
                break
        for i in range(len(leaf.shape) - 1, 0, -1):
            # never the already-assigned batch dim, never the max_len dim
            # (dynamic_update_slice target) — sizes may coincide, so the
            # check is positional via dims[i], not by size == batch
            if dims[i] is None and leaf.shape[i] != max_len \
                    and leaf.shape[i] % msize == 0:
                dims[i] = "model"
                break
        return P(*dims)
    return jax.tree.map(fn, cache_shapes)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
