"""Trace-time mesh context: lets model code insert sharding constraints
(GSPMD hints) without threading the mesh through every call signature.
``lower_cell`` installs the mesh before tracing; tests/examples that trace
without a mesh get no-op constraints."""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT: list = []   # (mesh, batch_axes)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, batch_axes: tuple | None = None):
    if batch_axes is None:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    _CURRENT.append((mesh, batch_axes))
    try:
        yield mesh
    finally:
        _CURRENT.pop()


def current_mesh() -> Mesh | None:
    return _CURRENT[-1][0] if _CURRENT else None


def dp_axes():
    return _CURRENT[-1][1] if _CURRENT else ()


def constrain(x, *spec_dims):
    """with_sharding_constraint if a mesh is installed; else identity.
    Dims longer than x.ndim are trimmed from the left (so callers can pass
    (dp, None, 'model') for both (B,S,V) and (B,V) logits)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    dims = list(spec_dims)[-x.ndim:] if len(spec_dims) > x.ndim \
        else list(spec_dims) + [None] * (x.ndim - len(spec_dims))
    # drop axis names absent from this mesh or already used by an earlier
    # dim (dp_over_model puts "model" into the batch axes); check
    # divisibility
    clean = []
    used: set = set()
    for d, size in zip(dims, x.shape):
        names = d if isinstance(d, tuple) else ((d,) if d else ())
        names = tuple(n for n in names
                      if n in mesh.axis_names and n not in used)
        total = 1
        for n in names:
            total *= mesh.shape[n]
        if names and size % total == 0:
            clean.append(names if len(names) > 1 else names[0])
            used.update(names)
        else:
            clean.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean)))
