"""Distributed-optimization collectives.

``compressed_psum`` applies the *paper's own split idea to the gradient
all-reduce*: gradients are reduced in bf16 (halving ICI bytes), and the
rounding residual is carried to the next step as an error-feedback buffer —
the same "keep the mantissa loss in an extra variable" trick as Eqs. (3)/(5),
applied across steps instead of across split terms. Used by the shard_map
trainer variant; validated numerically by tests/test_distribution.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum(grads, residual, axis_name: str):
    """bf16 all-reduce with error feedback.

    Returns (reduced_fp32, new_residual). The residual holds the f32-bf16
    rounding error of *this* device's contribution and is added back before
    the next compression — over steps the bias telescopes away."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        glo = g32.astype(jnp.bfloat16)
        new_r = g32 - glo.astype(jnp.float32)
        red = jax.lax.psum(glo.astype(jnp.float32), axis_name)
        return red, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def zeros_like_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
