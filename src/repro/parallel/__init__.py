from . import sharding
