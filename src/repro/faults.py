"""repro.faults — context-scoped, seed-deterministic fault injection.

The serving stack promises graceful degradation: a dry page pool delays
admission instead of crashing, a fused-kernel failure falls back to the
bit-specified XLA path (``kernels/guard.py``), a non-finite decode step
re-runs under the fallback numerics scope, a corrupt autotuner cache entry
reads as a miss.  None of those recovery paths can be trusted unless they
run — so this module makes every failure mode *injectable*, on demand and
deterministically, at named sites instrumented through the stack:

=====================  ====================================================
site                   effect at the instrumented callsite
=====================  ====================================================
``pool.alloc``         ``PagePool.alloc`` reports exhaustion (returns None)
``kernel.matmul``      fused GEMM dispatch raises (breaker sees a failure)
``kernel.attention``   fused flash-attention dispatch raises
``kernel.paged``       paged decode-attention dispatch raises
``decode.nonfinite``   engine poisons one slot's decode logits to NaN
``decode.slow``        engine step burns extra deadline ticks
``prefill``            engine prefill raises (group is re-queued)
``prefill.chunk``      one prefill chunk raises (request is re-queued)
``prefix.lookup``      prefix-cache lookup reports a miss (full prefill)
``tuning.cache``       autotuner cache read returns a corrupt entry
=====================  ====================================================

Usage mirrors :func:`repro.numerics.use` — a thread-local, nestable
context scope::

    from repro import faults
    plan = faults.FaultPlan([faults.FaultSpec("pool.alloc", at=(0, 1))])
    with faults.use(plan):
        ...   # the first two PagePool.alloc calls report exhaustion

Determinism is the design center: a plan fires as a pure function of the
per-site *invocation index* (every instrumented callsite calls
:func:`poke` exactly once per invocation, faulting or not), so the same
plan over the same workload yields the same trip sequence — probabilistic
specs (``p=``) draw from a stateless seeded hash of ``(seed, site,
index)``, never from shared RNG state.  ``plan.log`` records every fire
as ``(site, index)`` and is asserted reproducible in the chaos battery
(``tests/test_faults.py``).

The process-default plan parses from ``REPRO_FAULTS`` (registered in
:data:`repro.numerics.ENV_VARS`) — e.g. ``REPRO_FAULTS="pool.alloc@0:1;
decode.slow@every=4"`` — so a launch CLI can run under chaos without code
changes.  A :func:`use` scope always wins over the env plan.

With no active plan every ``poke`` is a cheap None — production traffic
pays one dict lookup per instrumented call, nothing else.
"""
from __future__ import annotations

import contextlib
import threading
import zlib
from dataclasses import dataclass

__all__ = [
    "SITES", "FaultSpec", "FaultPlan", "FaultInjected", "active", "use",
    "poke", "raise_if", "plan_from_spec", "env_plan",
]

# The canonical injection-site registry: poke() rejects unknown names so a
# typo'd site fails loudly instead of never firing.
SITES: dict[str, str] = {
    "pool.alloc": "PagePool.alloc reports exhaustion (returns None)",
    "kernel.matmul": "fused GEMM dispatch raises FaultInjected",
    "kernel.attention": "fused flash-attention dispatch raises FaultInjected",
    "kernel.paged": "paged decode-attention dispatch raises FaultInjected",
    "decode.nonfinite": "engine poisons a slot's decode logits to NaN "
                        "(arg = slot index, -1 = every slot)",
    "decode.slow": "engine step burns extra deadline ticks (arg = ticks)",
    "prefill": "engine prefill raises FaultInjected (group re-queued)",
    "prefill.chunk": "one prefill chunk raises FaultInjected (request "
                     "re-queued under the prefill 3-strike cap)",
    "prefix.lookup": "prefix-cache lookup reports a miss (degrades to a "
                     "full prefill, token-identical)",
    "tuning.cache": "autotuner cache read returns a corrupt entry",
}


class FaultInjected(RuntimeError):
    """The error an injected fault raises at raise-style sites."""


def _hash01(seed: int, site: str, index: int) -> float:
    """Stateless uniform draw in [0, 1) from (seed, site, index) — the
    probabilistic trigger never consumes shared RNG state, so p-specs stay
    deterministic per invocation regardless of what else runs."""
    h = zlib.crc32(f"{seed}/{site}/{index}".encode())
    return h / 2**32


@dataclass(frozen=True)
class FaultSpec:
    """One injection rule: *where* (``site``) and *when* it fires.

    Triggers compose as OR over: explicit invocation indices (``at``,
    0-based), a period (``every`` — fires on indices k-1, 2k-1, ...), and
    a seeded Bernoulli (``p``).  ``times`` caps total fires (-1 =
    unlimited); ``arg`` is a site-specific payload (slot index for
    ``decode.nonfinite``, tick count for ``decode.slow``).
    """
    site: str
    at: tuple[int, ...] = ()
    every: int = 0
    times: int = -1
    p: float = 0.0
    seed: int = 0
    arg: int = -1

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known: {sorted(SITES)}")
        object.__setattr__(self, "at", tuple(int(i) for i in self.at))

    def triggers(self, index: int) -> bool:
        """Whether this spec (budget aside) fires on invocation ``index``."""
        if index in self.at:
            return True
        if self.every > 0 and (index + 1) % self.every == 0:
            return True
        if self.p > 0.0 and _hash01(self.seed, self.site, index) < self.p:
            return True
        return False


class FaultPlan:
    """A set of :class:`FaultSpec` rules plus the runtime trip state.

    The state (per-site invocation counters, per-spec fire budgets, the
    ``log`` of fires) lives on the plan instance; entering a :func:`use`
    scope resets it, so re-running the same workload under the same plan
    reproduces the same trip sequence exactly.
    """

    def __init__(self, specs=()):
        self.specs: tuple[FaultSpec, ...] = tuple(
            s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in specs)
        self._by_site: dict[str, list[FaultSpec]] = {}
        for s in self.specs:
            self._by_site.setdefault(s.site, []).append(s)
        self.reset()

    def reset(self) -> "FaultPlan":
        self._counts: dict[str, int] = {}
        self._fired: dict[int, int] = {i: 0 for i in range(len(self.specs))}
        self.log: list[tuple[str, int]] = []
        return self

    def counts(self) -> dict[str, int]:
        """Per-site invocation counters (faulting or not)."""
        return dict(self._counts)

    def poke(self, site: str) -> FaultSpec | None:
        """Advance ``site``'s invocation counter; return the firing spec
        (first match with budget left) or None."""
        if site not in SITES:
            raise KeyError(f"unknown fault site {site!r}; "
                           f"known: {sorted(SITES)}")
        index = self._counts.get(site, 0)
        self._counts[site] = index + 1
        for i, spec in enumerate(self.specs):
            if spec.site != site:
                continue
            if spec.times >= 0 and self._fired[i] >= spec.times:
                continue
            if spec.triggers(index):
                self._fired[i] += 1
                self.log.append((site, index))
                return spec
        return None


# ------------------------------------------------- context + env default

_tls = threading.local()
_ENV_PLAN: FaultPlan | None = None
_ENV_PLAN_LOADED = False
_env_lock = threading.Lock()


def _stack() -> list:
    try:
        return _tls.stack
    except AttributeError:
        _tls.stack = []
        return _tls.stack


def env_plan() -> FaultPlan | None:
    """The process-default plan parsed from ``REPRO_FAULTS`` (None when
    unset — the common case).  Parsed once; tests that monkeypatch the
    env can call :func:`reload_env_plan`."""
    global _ENV_PLAN, _ENV_PLAN_LOADED
    if not _ENV_PLAN_LOADED:
        with _env_lock:
            if not _ENV_PLAN_LOADED:
                from repro import numerics
                spec = numerics.env_value("REPRO_FAULTS")
                _ENV_PLAN = plan_from_spec(spec) if spec else None
                _ENV_PLAN_LOADED = True
    return _ENV_PLAN


def reload_env_plan() -> FaultPlan | None:
    """Re-parse ``REPRO_FAULTS`` (tests; long-lived processes)."""
    global _ENV_PLAN_LOADED
    with _env_lock:
        _ENV_PLAN_LOADED = False
    return env_plan()


def active() -> FaultPlan | None:
    """The innermost :func:`use` plan on this thread, else the env plan."""
    stack = _stack()
    return stack[-1] if stack else env_plan()


@contextlib.contextmanager
def use(plan: FaultPlan | None = None, *specs, reset: bool = True):
    """Scoped fault plan: ``with faults.use(plan): ...``.

    Accepts a :class:`FaultPlan`, or :class:`FaultSpec` instances directly
    (``faults.use(FaultSpec("pool.alloc", at=(0,)))``).  ``reset=True``
    (default) zeroes the plan's trip state on entry so every scope replays
    the same deterministic schedule.  ``use(None)`` masks any outer/env
    plan (a fault-free inner scope).
    """
    if plan is not None and not isinstance(plan, FaultPlan):
        specs = (plan,) + specs
        plan = None
    if specs:
        plan = FaultPlan(specs)
    if plan is not None and reset:
        plan.reset()
    stack = _stack()
    stack.append(plan)
    try:
        yield plan
    finally:
        stack.pop()


def poke(site: str) -> FaultSpec | None:
    """The instrumentation hook: advance ``site``'s counter on the active
    plan and return the firing spec, or None (also when no plan is
    active — the production fast path)."""
    plan = active()
    if plan is None:
        return None
    return plan.poke(site)


def raise_if(site: str) -> None:
    """Raise :class:`FaultInjected` when the active plan fires ``site``."""
    spec = poke(site)
    if spec is not None:
        raise FaultInjected(f"injected fault at {site!r}")


# ------------------------------------------------------------ env spec

def plan_from_spec(spec: str) -> FaultPlan:
    """Parse a ``REPRO_FAULTS`` string into a :class:`FaultPlan`.

    Grammar: ``;``-separated clauses, each ``site@token[:token...]``.
    A bare-integer token adds an ``at`` index; ``key=value`` tokens set
    ``every``/``times``/``p``/``seed``/``arg``.  Examples::

        pool.alloc@0:1                # first two allocs fail
        decode.slow@every=4:arg=3     # every 4th step burns 3 ticks
        kernel.matmul@p=0.25:seed=7   # seeded 25% of dispatches fail
    """
    out = []
    for clause in spec.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        site, sep, rest = clause.partition("@")
        if not sep:
            raise ValueError(f"bad fault clause {clause!r}: expected "
                             "site@trigger[:trigger...]")
        kw: dict = {"site": site.strip(), "at": []}
        for token in rest.split(":"):
            token = token.strip()
            if not token:
                continue
            key, eq, val = token.partition("=")
            if not eq:
                kw["at"].append(int(token))
            elif key in ("every", "times", "seed", "arg"):
                kw[key] = int(val)
            elif key == "p":
                kw["p"] = float(val)
            else:
                raise ValueError(f"bad fault token {token!r} in {clause!r}")
        kw["at"] = tuple(kw["at"])
        out.append(FaultSpec(**kw))
    return FaultPlan(out)
