"""Fault-tolerant training loop.

Production posture (1000+-node design; every mechanism is exercised by the
CPU test suite at small scale):

  * checkpoint/restart — atomic sharded saves every ``ckpt_every`` steps
    (keep-k retention + integrity hashes); on start, the loop resumes from
    the newest intact checkpoint and replays the data stream
    deterministically (``data.pipeline`` seeds by (run_seed, step)).
  * straggler watchdog — an EMA of step wall-time; a step slower than
    ``straggler_factor`` x EMA raises a StragglerEvent. On a real cluster
    the runner responds by emergency-checkpointing and excluding the slow
    host from the next elastic restart; here the event triggers the
    emergency save path (same code).
  * preemption hook — SIGTERM triggers an emergency checkpoint before exit
    (standard TPU-pod maintenance handling).
  * elastic restart — checkpoints are mesh-agnostic (host-gathered arrays +
    manifest), so a job restarted on a different mesh re-shards on load
    (checkpoint.manager.restore with new shardings).
"""
from __future__ import annotations

import contextlib
import signal
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, device_batch, host_batch
from repro.models import get_model
from repro.optim import adamw


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    ema_decay: float = 0.8


class StragglerEvent(RuntimeError):
    pass


def train(cfg, opt_cfg: adamw.OptConfig, data_cfg: DataConfig,
          loop_cfg: TrainLoopConfig, ckpt_dir: str,
          train_step=None, shardings=None, log=print, mesh=None):
    """Run (or resume) a training job; returns (state, history).

    ``mesh``: an optional GSPMD mesh.  When given, the step jits with the
    framework's param/optimizer shardings (``launch.step.
    make_sharded_train_step``), batches land pre-sharded on the data axes,
    and the whole loop runs under ``parallel.ctx.use_mesh`` — so kernel
    dispatch sees the mesh at trace time and routes eligible contractions
    and attention through the ``shard_map``-wrapped Pallas kernels
    (``kernels/shmap.py``) instead of declining to the XLA fallback.
    """
    from repro.parallel import ctx as pctx
    from repro.parallel import sharding as shd
    model = get_model(cfg)
    batch_sharder = None
    if train_step is None:
        if mesh is not None:
            from repro.launch.step import make_sharded_train_step
            train_step, state_sh, batch_sharder = make_sharded_train_step(
                cfg, opt_cfg, mesh)
            if shardings is None:
                shardings = state_sh
        else:
            from repro.launch.step import make_train_step
            train_step = jax.jit(make_train_step(cfg, opt_cfg),
                                 donate_argnums=0)
    mesh_scope = (pctx.use_mesh(mesh, shd.batch_axes(cfg, mesh))
                  if mesh is not None else contextlib.nullcontext())
    with mesh_scope:
        return _run(cfg, opt_cfg, data_cfg, loop_cfg, ckpt_dir, train_step,
                    shardings, log, model, batch_sharder)


def _run(cfg, opt_cfg, data_cfg, loop_cfg, ckpt_dir, train_step, shardings,
         log, model, batch_sharder):

    # ---- resume or init ---------------------------------------------------
    start = ckpt.latest_step(ckpt_dir)
    if start is not None:
        abstract = {
            "params": jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
        }
        abstract["opt"] = jax.eval_shape(
            lambda: adamw.init_state(abstract["params"], opt_cfg))
        state = ckpt.restore(ckpt_dir, start, abstract, shardings)
        log(f"[resume] restored step {start} from {ckpt_dir}")
        step0 = start
    else:
        params = model.init(jax.random.PRNGKey(data_cfg.seed))
        state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
        step0 = 0

    # ---- preemption hook -------------------------------------------------
    interrupted = {"flag": False}

    def _sigterm(signum, frame):
        interrupted["flag"] = True
    old_handler = signal.signal(signal.SIGTERM, _sigterm)

    history = []
    ema = None
    batch_sh = None
    try:
        for step in range(step0, loop_cfg.total_steps):
            if batch_sharder is not None and batch_sh is None:
                batch_sh = batch_sharder(host_batch(cfg, data_cfg, step))
            batch = device_batch(cfg, data_cfg, step, shardings=batch_sh)
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            history.append({"step": step + 1, "loss": loss, "time_s": dt})

            # straggler watchdog
            if ema is not None and dt > loop_cfg.straggler_factor * ema \
                    and step > step0 + 3:
                ckpt.save(ckpt_dir, step + 1, state)
                ckpt.retain(ckpt_dir, loop_cfg.keep)
                raise StragglerEvent(
                    f"step {step+1} took {dt:.3f}s vs EMA {ema:.3f}s — "
                    f"emergency checkpoint written")
            ema = dt if ema is None else (loop_cfg.ema_decay * ema
                                          + (1 - loop_cfg.ema_decay) * dt)

            if (step + 1) % loop_cfg.ckpt_every == 0 or interrupted["flag"]:
                ckpt.save(ckpt_dir, step + 1, state)
                ckpt.retain(ckpt_dir, loop_cfg.keep)
                log(f"[ckpt] step {step+1} loss {loss:.4f}")
            if interrupted["flag"]:
                log("[preempt] SIGTERM — emergency checkpoint done")
                break
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at {step+1}")
    finally:
        signal.signal(signal.SIGTERM, old_handler)
    return state, history
