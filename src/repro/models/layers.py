"""Shared neural layers. Every contraction routes through repro.core.pdot,
so the paper's error-corrected GEMM is a config knob for the whole zoo."""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pdot
from .modules import dense_init, ones, split_keys, zeros

NEG_INF = -2.0e38


# ------------------------------------------------------------------ norms

def rmsnorm(scale, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))


def layernorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


# ------------------------------------------------------------------- rope

def rope(x, positions, theta: float):
    """Rotary embedding. x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


# ------------------------------------------------------------- attention

def attn_init(key, cfg):
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), fan_in=D),
        "wk": dense_init(ks[1], (D, Hkv, hd), fan_in=D),
        "wv": dense_init(ks[2], (D, Hkv, hd), fan_in=D),
        "wo": dense_init(ks[3], (H, hd, D), fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((H, hd))
        p["bk"] = zeros((Hkv, hd))
        p["bv"] = zeros((Hkv, hd))
    if cfg.qk_norm:
        p["q_norm"] = zeros((hd,))
        p["k_norm"] = zeros((hd,))
    return p


def _project_qkv(p, x, cfg, positions):
    pol = cfg.policy
    q = pdot("bsd,dhk->bshk", x, p["wq"], pol)
    k = pdot("bsd,dhk->bshk", x, p["wk"], pol)
    v = pdot("bsd,dhk->bshk", x, p["wv"], pol)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos, k_pos, causal: bool, window):
    """Additive mask from position vectors. window may be a traced scalar
    (0 = unlimited) so local/global layers share one scanned code path."""
    d = q_pos[:, None] - k_pos[None, :]
    ok = (d >= 0) if causal else jnp.ones_like(d, dtype=bool)
    ok &= jnp.where(window > 0, d < window, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def mha(q, k, v, cfg, q_pos, k_pos, causal=True, window=0):
    """Materialized-scores attention (short sequences).

    q: (B, S, H, d); k/v: (B, T, Hkv, d). GQA via head grouping — no KV
    repetition is materialized."""
    from repro.parallel import ctx
    B, S, H, hd = q.shape
    Hkv, hdv = k.shape[2], v.shape[3]
    rep = H // Hkv
    qg = q.reshape(B, S, Hkv, rep, hd)
    # context parallelism: shard the q-sequence on the model axis so the
    # S x S score block shrinks 16x per device regardless of kv-head count
    qg = ctx.constrain(qg, ctx.dp_axes(), "model", None, None, None)
    scores = pdot("bqhrd,bkhd->bhrqk", qg, k, cfg.mix_policy)
    scores = ctx.constrain(scores, ctx.dp_axes(), None, None, "model", None)
    scores = scores / np.sqrt(hd)
    scores = softcap(scores, cfg.attn_softcap)
    scores = scores + _mask_bias(q_pos[0], k_pos[0], causal, window)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = pdot("bhrqk,bkhd->bqhrd", probs, v, cfg.mix_policy)
    out = ctx.constrain(out, ctx.dp_axes(), None, None, "model", None)
    return out.reshape(B, S, H, hdv)


def blocked_attention(q, k, v, cfg, q_pos, k_pos, causal=True, window=0,
                      q_chunk=2048, k_chunk=2048):
    """Flash-style attention: O(S·chunk) memory, online softmax over KV
    blocks — required for the 32k prefill cells.

    Causal short-circuit: a KV chunk whose every position lies strictly in
    the causal future of the whole q chunk (``min(k_pos) > max(q_pos)``)
    carries only ``-inf`` scores — its probability mass underflows to
    exactly 0 — so its GEMMs are skipped via ``lax.cond`` inside the scan
    (~2x FLOPs saved on causal prefill, ``ki > qi`` chunks for the models'
    ``arange`` positions).  The predicate is position-based, so it is
    correct for any nondecreasing positions (ties included), works with a
    traced ``window``, and stays reverse-differentiable (``cond``, unlike a
    dynamic-bound ``fori_loop``, has a VJP)."""
    B, S, H, hd = q.shape
    T, Hkv, hdv = k.shape[1], k.shape[2], v.shape[3]
    rep = H // Hkv
    nq, nk = S // q_chunk, T // k_chunk
    assert S % q_chunk == 0 and T % k_chunk == 0, (S, T, q_chunk, k_chunk)
    qg = q.reshape(B, nq, q_chunk, Hkv, rep, hd)
    kg = k.reshape(B, nk, k_chunk, Hkv, hd)
    vg = v.reshape(B, nk, k_chunk, Hkv, hdv)
    qp = q_pos[0].reshape(nq, q_chunk)
    kp = k_pos[0].reshape(nk, k_chunk)
    scale = 1.0 / np.sqrt(hd)

    from repro.parallel import ctx

    def q_block(qi):
        qblk = qg[:, qi]                     # (B, qc, Hkv, rep, hd)
        qblk = ctx.constrain(qblk, ctx.dp_axes(), "model", None, None, None)
        qpos = qp[qi]

        def kv_step(carry, ki):
            def live(c):
                m, l, acc = c
                s = pdot("bqhrd,bkhd->bhrqk", qblk, kg[:, ki],
                         cfg.mix_policy) * scale
                s = ctx.constrain(s, ctx.dp_axes(), None, None, "model",
                                  None)
                s = softcap(s, cfg.attn_softcap)
                s = s + _mask_bias(qpos, kp[ki], causal, window)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                pv = pdot("bhrqk,bkhd->bhrqd", p, vg[:, ki], cfg.mix_policy)
                acc_new = acc * corr[..., None] + pv
                return (m_new, l_new, acc_new)

            if causal:
                needed = jnp.min(kp[ki]) <= jnp.max(qpos)
                return jax.lax.cond(needed, live, lambda c: c, carry), None
            return live(carry), None

        m0 = jnp.full((B, Hkv, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, rep, q_chunk, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,Hkv,rep,qc,hdv)
        return jnp.transpose(out, (0, 3, 1, 2, 4))        # (B,qc,Hkv,rep,hdv)

    out = jax.lax.map(q_block, jnp.arange(nq))            # (nq,B,qc,Hkv,rep,hdv)
    out = jnp.transpose(out, (1, 0, 2, 3, 4, 5)).reshape(B, S, H, hdv)
    return out


ATTN_BLOCK_THRESHOLD = 8192


def _sdpa_composition(q, k, v, cfg, q_pos, k_pos, causal, window):
    """The pdot-composition path: blocked for long sequences, mha else.

    Blocked needs chunk-divisible S/T; the fused kernel pads internally,
    so shapes reachable only through the fused forward (e.g. its recompute
    backward) fall to mha when the chunk grid doesn't divide."""
    if (q.shape[1] >= ATTN_BLOCK_THRESHOLD
            and q.shape[1] % 2048 == 0 and k.shape[1] % 2048 == 0):
        return blocked_attention(q, k, v, cfg, q_pos, k_pos, causal, window)
    return mha(q, k, v, cfg, q_pos, k_pos, causal, window)


# The Pallas attention kernel has no VJP of its own, so the fused route is
# wrapped in a custom_vjp whose backward *recomputes* attention through the
# pdot composition and differentiates that — the same policy-preserving
# recompute discipline as fused_linear's backward (flash-attention
# backwards recompute the forward anyway; the composition's pdots carry
# their own custom_vjp, so the gradient GEMMs still dispatch to the fused
# GEMM kernel).  Without this, jax.grad through a dispatched attention
# call would fail at trace time on every training step.

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _fused_sdpa(q, k, v, q_pos, k_pos, window, causal, policy_name, softcap):
    from repro.kernels import dispatch
    out = dispatch.attention(q, k, v, policy=policy_name, q_pos=q_pos,
                             k_pos=k_pos, causal=causal, window=window,
                             softcap=softcap)
    assert out is not None, "caller must pre-check dispatch.attention_eligible"
    return out


def _fused_sdpa_fwd(q, k, v, q_pos, k_pos, window, causal, policy_name,
                    softcap):
    out = _fused_sdpa(q, k, v, q_pos, k_pos, window, causal, policy_name,
                      softcap)
    return out, (q, k, v, q_pos, k_pos, window)


def _fused_sdpa_bwd(causal, policy_name, softcap, res, g):
    import types
    q, k, v, q_pos, k_pos, window = res
    cfg = types.SimpleNamespace(mix_policy=policy_name, attn_softcap=softcap)

    def ref(q, k, v):
        return _sdpa_composition(q, k, v, cfg, q_pos, k_pos, causal, window)

    _, vjp = jax.vjp(ref, q, k, v)
    dq, dk, dv = vjp(g.astype(jnp.float32))

    def z(x):   # int operands (positions / window) take float0 cotangents
        return np.zeros(np.shape(x), dtype=jax.dtypes.float0)

    return dq, dk, dv, z(q_pos), z(k_pos), z(window)


_fused_sdpa.defvjp(_fused_sdpa_fwd, _fused_sdpa_bwd)


def sdpa(q, k, v, cfg, q_pos, k_pos, causal=True, window=0):
    """Scaled-dot-product attention router — the single entry every model
    self-attention variant goes through.

    Takes the fused TCEC flash-attention kernel when
    ``kernels.dispatch.attention_eligible`` says so (declines off-TPU
    without force, for plain policies, below ``min_dim``, or under either
    escape hatch), with the recompute backward above; otherwise the pdot
    composition — ``blocked_attention`` for long sequences,
    materialized-scores ``mha`` else.  Under an installed GSPMD mesh the
    fused route runs per device through the ``shard_map`` wrapper
    (``kernels/shmap.py``: heads or q-sequence on ``model``, batch on the
    data axes); specs the wrapper doesn't support — and
    ``use(shard_map=False)`` / ``REPRO_SHARD_MAP=0`` — keep the pdot
    composition, which carries the context-parallel sharding constraints.
    The composition is also the kernel's verification oracle
    (tests/test_attention.py)."""
    from repro.core.policy import get_policy
    from repro.kernels import dispatch
    if dispatch.attention_eligible(q, k, v, policy=cfg.mix_policy):
        return _fused_sdpa(q, k, v, q_pos, k_pos, window, causal,
                           get_policy(cfg.mix_policy).name, cfg.attn_softcap)
    return _sdpa_composition(q, k, v, cfg, q_pos, k_pos, causal, window)


def attention(p, x, cfg, positions, causal=True, window=0):
    """Full attention layer: qkv -> sdpa (fused or blocked) -> out proj."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = sdpa(q, k, v, cfg, positions, positions, causal, window)
    return pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)


def _decode_attend(q, ck, cv, cfg, cur_pos, window=0):
    """One-token attention over a dense-layout cache view.

    q: (B, 1, H, hd); ck/cv: (B, T, Hkv, d) — either the dense cache or a
    page gather (serving).  ``cur_pos`` is the current token's position:
    a scalar (dense decode, batch-uniform) or a (B,) vector (continuous
    batching, one in-flight length per slot).

    Cache dots run in bf16: the cache is already bf16 (splitting it is
    pointless — the residual is exactly zero) and f32 upcasts would copy
    the whole cache per step.
    """
    B, T, Hkv = ck.shape[0], ck.shape[1], ck.shape[2]
    H, hd = q.shape[2], q.shape[3]
    rep = H // Hkv
    qg = q.reshape(B, 1, Hkv, rep, hd)
    s = pdot("bqhrd,bkhd->bhrqk", qg, ck, "bf16")
    s = softcap(s / np.sqrt(hd), cfg.attn_softcap)
    # mask by k_pos <= cur_pos directly: one O(T) validity vector per
    # step (never a (T, T) _mask_bias intermediate).  A select, not an
    # additive bias: the stale cache tail may hold non-finite garbage
    # (inf + NEG_INF = inf, NaN + anything = NaN would leak through).
    cur = jnp.asarray(cur_pos, jnp.int32).reshape(-1, 1)      # (B or 1, 1)
    d = cur - jnp.arange(T, dtype=jnp.int32)[None]            # (B or 1, T)
    ok = d >= 0
    ok &= jnp.where(window > 0, d < window, True)
    s = jnp.where(ok[:, None, None, None, :], s, jnp.float32(NEG_INF))
    pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = pdot("bhrqk,bkhd->bqhrd", pr, cv, "bf16")
    return o.reshape(B, 1, H, cv.shape[3])


def attention_decode(p, x, cfg, cache, cache_index, window=0):
    """One-token decode against a (B, T, Hkv, d) KV cache."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cache_index, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, cache_index, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, cache_index, 0, 0))
    o = _decode_attend(q, ck, cv, cfg, cache_index, window)
    out = pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)
    return out, {"k": ck, "v": cv}


def attention_prefill(p, x, cfg, positions, window=0):
    """Full attention layer that also returns the K/V it computed, so a
    sequence-level prefill can fill a cache in ONE jitted forward instead
    of S sequential ``attention_decode`` calls.  Same math as
    :func:`attention` (the fused sdpa route included)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = sdpa(q, k, v, cfg, positions, positions, True, window)
    out = pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)
    return out, {"k": k, "v": v}


def attention_chunk(p, x, cfg, cache, start, window=0):
    """One prefill *chunk* against a dense scratch cache (chunked prefill).

    x: (B, C, d_model) — chunk tokens at absolute positions ``start ..
    start + C``; cache: an :func:`attention_prefill`-layout dense cache
    ``{"k", "v"}`` with leaves (B, T, Hkv, d) holding every earlier
    chunk's exact K/V (and, on a prefix-cache hit, the gathered shared
    pages).  The chunk's own K/V is written in, then attention runs over
    the full [0, T) key range through the same :func:`sdpa` router as the
    monolithic prefill — the causal mask hides positions ``>= start + C``
    (zero-initialized scratch stays finite, so the additive mask bias is
    safe), which makes each chunk row bitwise-equal to the corresponding
    monolithic prefill row when the cache is f32.
    """
    B, C = x.shape[:2]
    positions = jnp.broadcast_to(
        start + jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    q, k, v = _project_qkv(p, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, start, 0, 0))
    T = ck.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    o = sdpa(q, ck, cv, cfg, positions, k_pos, True, window)
    out = pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)
    return out, {"k": ck, "v": cv}


def attention_decode_paged(p, x, cfg, pool, block_tables, lengths, window=0):
    """One-token decode against a paged KV cache (serving engine).

    x: (B, 1, d_model) — one token per sequence slot; pool: ``{"k": (NP,
    ps, Hkv, hd), "v": (NP, ps, Hkv, hdv)}`` page arrays shared across
    slots; block_tables: (B, maxp) i32 page indices per slot; lengths:
    (B,) i32 tokens already cached per slot (the current token's position).

    The new token's K/V is scattered into its slot's current page, then
    attention runs through ``dispatch.attention_decode`` (the fused paged
    kernel) when eligible, else gathers the block table into a dense view
    and applies exactly the :func:`attention_decode` math — bitwise the
    same attend as the dense cache path, which is what makes the engine's
    greedy output token-identical to the legacy dense ``generate()``.
    """
    from repro.kernels import dispatch
    B = x.shape[0]
    positions = lengths[:, None].astype(jnp.int32)            # (B, 1)
    q, k, v = _project_qkv(p, x, cfg, positions)
    ps = pool["k"].shape[1]
    maxp = block_tables.shape[1]
    page = block_tables[jnp.arange(B), lengths // ps]         # (B,)
    off = lengths % ps
    ck = pool["k"].at[page, off].set(k[:, 0].astype(pool["k"].dtype))
    cv = pool["v"].at[page, off].set(v[:, 0].astype(pool["v"].dtype))
    fused = dispatch.attention_decode(q[:, 0], ck, cv, block_tables,
                                      lengths + 1, policy=cfg.mix_policy,
                                      window=window,
                                      softcap=cfg.attn_softcap)
    if fused is not None:
        o = fused[:, None].astype(jnp.float32)                # (B, 1, H, hdv)
    else:
        Hkv, hd = ck.shape[2], ck.shape[3]
        kg = ck[block_tables].reshape(B, maxp * ps, Hkv, hd)
        vg = cv[block_tables].reshape(B, maxp * ps, Hkv, cv.shape[3])
        o = _decode_attend(q, kg, vg, cfg, lengths, window)
    out = pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)
    return out, {"k": ck, "v": cv}


# ------------------------------------------------------------------- MLP

def mlp_init(key, cfg, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], (D, F), fan_in=D),
        "w_up": dense_init(ks[1], (D, F), fan_in=D),
        "w_down": dense_init(ks[2], (F, D), fan_in=F),
    }


def _act(x, kind: str):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


# ------------------------------------------------- fused linear epilogue
#
# When the numerics config enables epilogue fusion (REPRO_FUSE_EPILOGUE or
# repro.numerics.use(fuse_epilogue=True)), act(x @ W + b) runs as ONE fused
# Pallas kernel call: the bias add and activation fold into the kernel's
# scaled epilogue on the last K step, so the pre-activation never round-trips
# HBM. The backward stays policy-preserving: it recomputes the pre-activation
# with the same policy GEMM and routes dx/dW through pdot (which itself
# dispatches), exactly like the unfused path's custom_vjp.
#
# NB the fused forward flattens (B, S, D) -> (B*S, D) for the 2-D kernel;
# under GSPMD that reshape can replicate a sharded batch dim, so fusion is
# an opt-in serving/throughput knob, not the training default.

def _epilogue_act(z, activation):
    """The exact activation set the kernel epilogue supports — keyed by the
    same table, so fused and unfused paths can never disagree on semantics
    (``_act``'s anything-but-gelu-means-silu default is NOT safe here)."""
    from repro.kernels.tcec_matmul import EPILOGUE_ACTIVATIONS
    return EPILOGUE_ACTIVATIONS[activation](z)


def _linear_unfused(x, w, b, activation, policy):
    z = pdot("bsd,df->bsf", x, w, policy)
    if b is not None:
        z = z + b
    return _epilogue_act(z, activation)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_linear(x, w, b, activation, policy):
    """act(x @ w + b) with the epilogue fused into the TCEC kernel when the
    dispatch config allows it; reference pdot path otherwise.

    x: (B, S, D); w: (D, F); b: (F,) or None; activation: None|"gelu"|"silu".
    """
    from repro import numerics
    from repro.kernels import dispatch, ops
    from repro.core.policy import get_policy
    pol = get_policy(policy)
    B, S, D = x.shape
    F = w.shape[-1]
    cfg = numerics.active()
    if (dispatch.epilogue_eligible(pol, cfg)
            and min(B * S, D, F) >= cfg.min_dim):
        x2 = x.reshape(B * S, D)
        block = dispatch.tuned_block(B * S, F, D, pol.name, cfg=cfg)
        out = ops.tcec_matmul(x2, w, policy=pol.name, block=block,
                              interpret=cfg.interpret, bias=b,
                              activation=activation, cfg=cfg)
        return out.reshape(B, S, F)
    return _linear_unfused(x, w, b, activation, policy)


def _fused_linear_fwd(x, w, b, activation, policy):
    return fused_linear(x, w, b, activation, policy), (x, w, b)


def _fused_linear_bwd(activation, policy, res, dy):
    x, w, b = res
    if activation:
        # recompute the pre-activation under the same policy (policy-
        # preserving backward, same discipline as _make_dg's custom_vjp)
        z = _linear_unfused(x, w, b, None, policy)
        _, act_vjp = jax.vjp(lambda t: _epilogue_act(t, activation), z)
        dz = act_vjp(dy)[0]
    else:
        dz = dy
    dx = pdot("bsf,df->bsd", dz, w, policy)
    dw = pdot("bsd,bsf->df", x, dz, policy)
    db = jnp.sum(dz, axis=(0, 1)).astype(b.dtype) if b is not None else None
    return dx.astype(x.dtype), dw.astype(w.dtype), db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def mlp(p, x, cfg):
    from repro import numerics
    if numerics.active().fuse_epilogue:
        g = fused_linear(x, p["w_gate"], None, cfg.activation, cfg.policy)
        u = fused_linear(x, p["w_up"], None, None, cfg.policy)
        return pdot("bsf,fd->bsd", g * u, p["w_down"], cfg.policy)
    g = pdot("bsd,df->bsf", x, p["w_gate"], cfg.policy)
    u = pdot("bsd,df->bsf", x, p["w_up"], cfg.policy)
    h = _act(g, cfg.activation) * u
    return pdot("bsf,fd->bsd", h, p["w_down"], cfg.policy)


# ------------------------------------------------------------------- MoE

def moe_init(key, cfg):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), fan_in=D),
        "w_gate": dense_init(ks[1], (E, D, F), fan_in=D),
        "w_up": dense_init(ks[2], (E, D, F), fan_in=D),
        "w_down": dense_init(ks[3], (E, F, D), fan_in=F),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg,
                               d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def moe(p, x, cfg):
    """GShard-style top-k MoE with capacity + one-hot dispatch einsums.

    Token groups of ``cfg.moe_groups`` bound the dispatch-tensor size and the
    dispatch-FLOPs overhead (~gs*cf/3F of expert FLOPs; see DESIGN.md)."""
    B, S, D = x.shape
    E, K, F = cfg.n_experts, cfg.moe_top_k, cfg.moe_d_ff
    N = B * S
    gs = min(cfg.moe_groups, N)
    while N % gs:            # largest divisor <= target (MTP runs S-1)
        gs -= 1
    G = N // gs
    xg = x.reshape(G, gs, D)

    logits = pdot("gsd,de->gse", xg, p["router"], "fp32")
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, K)                  # (G, gs, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    C = int(np.ceil(gs * K / E * cfg.capacity_factor / 4) * 4)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)   # (G, gs, K, E)
    flat = onehot.reshape(G, gs * K, E)
    # position-within-expert over (s, k) slot order. associative_scan, NOT
    # jnp.cumsum: XLA lowers big cumsums to a triangular matmul whose fake
    # FLOPs would dwarf the expert GEMMs (log-depth adds instead).
    pos = jax.lax.associative_scan(jnp.add, flat, axis=1)
    pos_t = ((pos - 1.0) * flat).sum(-1).reshape(G, gs, K)
    keep = (pos_t < C).astype(jnp.bfloat16)               # (G, gs, K)
    posc = jnp.clip(pos_t, 0, C - 1).astype(jnp.int32)
    oh_c = jax.nn.one_hot(posc, C, dtype=jnp.bfloat16)    # (G, gs, K, C)
    oh_e = onehot.astype(jnp.bfloat16)                    # (G, gs, K, E)
    # K-unrolled outer products: only (G, gs, E, C) accumulators live —
    # never a K-expanded (G, gs, K, E, C) tensor.
    dispatch = jnp.zeros((G, gs, E, C), jnp.bfloat16)
    combine = jnp.zeros((G, gs, E, C), jnp.bfloat16)
    for k in range(K):
        t = (oh_e[:, :, k, :, None] * oh_c[:, :, k, None, :]
             * keep[:, :, k, None, None])
        dispatch = dispatch + t
        combine = combine + t * topv[:, :, k, None, None].astype(jnp.bfloat16)

    xe = pdot("gsec,gsd->gecd", dispatch,
              xg.astype(jnp.bfloat16), "bf16")            # all-to-all under EP
    hg = pdot("gecd,edf->gecf", xe, p["w_gate"], cfg.policy)
    hu = pdot("gecd,edf->gecf", xe, p["w_up"], cfg.policy)
    he = _act(hg, cfg.activation) * hu
    ye = pdot("gecf,efd->gecd", he, p["w_down"], cfg.policy)
    y = pdot("gsec,gecd->gsd", combine,
             ye.astype(jnp.bfloat16), "bf16")
    y = y.reshape(B, S, D)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x, cfg)
    # load-balancing auxiliary (GShard aux loss), returned for training
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(onehot.sum(2), axis=(0, 1))
    aux = jnp.sum(me * ce) * E
    return y, aux
