"""Mamba-2 SSD (state-space duality) layer — chunked matmul formulation.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) recasts the selective-SSM
recurrence as chunk-local matmuls plus a tiny inter-chunk state scan, which
makes it MXU-friendly — and every chunk matmul here routes through the
paper's TCEC policy via ``pdot``, so the error-corrected GEMM covers the
SSM family too (DESIGN.md §Arch-applicability).

Memory discipline: the sequence is processed with ``lax.scan`` over chunks
(one (B, H, Q, Q) score block live at a time) and all head-group expansions
use reshapes H = G x rep instead of materialized repeats.

Sharding discipline: the input projection is stored as separate z / x / B /
C / dt tensors (not one fused matrix) so each output dim shards cleanly on
the ``model`` axis without split-at-unaligned-boundary resharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pdot
from .modules import dense_init, split_keys, zeros
from .layers import rmsnorm


def ssd_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def ssd_init(key, cfg):
    D = cfg.d_model
    d_inner, H = ssd_dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state
    ks = split_keys(key, 9)
    return {
        "wz": dense_init(ks[8], (D, d_inner), fan_in=D),
        "wx": dense_init(ks[1], (D, d_inner), fan_in=D),
        "wb": dense_init(ks[2], (D, G * N), fan_in=D),
        "wc": dense_init(ks[3], (D, G * N), fan_in=D),
        "wdt": dense_init(ks[4], (D, H), fan_in=D),
        "conv_x": dense_init(ks[5], (cfg.ssm_conv, d_inner), fan_in=cfg.ssm_conv),
        "conv_b": dense_init(ks[6], (cfg.ssm_conv, G * N), fan_in=cfg.ssm_conv),
        "conv_c": dense_init(ks[7], (cfg.ssm_conv, G * N), fan_in=cfg.ssm_conv),
        "conv_bias_x": zeros((d_inner,)),
        "conv_bias_b": zeros((G * N,)),
        "conv_bias_c": zeros((G * N,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D_skip": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))),  # softplus^-1
        "norm": zeros((d_inner,)),
        "w_out": dense_init(ks[0], (d_inner, D), fan_in=d_inner),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv, width K: y_t = sum_k x_{t-K+1+k} * w_k."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, k:k + x.shape[1], :] * w[k] for k in range(K))
    return jax.nn.silu(y + b)


def _project(p, x, cfg):
    z = pdot("bsd,de->bse", x, p["wz"], cfg.policy)
    xs = pdot("bsd,de->bse", x, p["wx"], cfg.policy)
    Bm = pdot("bsd,de->bse", x, p["wb"], cfg.policy)
    Cm = pdot("bsd,de->bse", x, p["wc"], cfg.policy)
    dt = pdot("bsd,de->bse", x, p["wdt"], cfg.policy)
    return z, xs, Bm, Cm, dt


def ssd_layer(p, x, cfg):
    """Train/prefill path. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    d_inner, H = ssd_dims(cfg)
    G, N, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    rep = H // G
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    pol = cfg.mix_policy

    z, xs, Bm, Cm, dt = _project(p, x, cfg)
    xs = _causal_conv(xs, p["conv_x"], p["conv_bias_x"])
    Bm = _causal_conv(Bm, p["conv_b"], p["conv_bias_b"])
    Cm = _causal_conv(Cm, p["conv_c"], p["conv_bias_c"])

    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,) < 0
    dts = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xbar = xs.reshape(B, S, H, P) * dts[..., None]
    dA = (dts * A).reshape(B, nc, Q, G, rep)
    cum = jnp.cumsum(dA, axis=2)                                 # (B,nc,Q,G,r)

    # chunk-major layouts for the scan
    def cmajor(t, shape):
        return jnp.moveaxis(t.reshape(shape), 1, 0)
    Bc = cmajor(Bm, (B, nc, Q, G, N))        # (nc,B,Q,G,N)
    Cc = cmajor(Cm, (B, nc, Q, G, N))
    Xc = cmajor(xbar, (B, nc, Q, G, rep, P))  # (nc,B,Q,G,r,P)
    Lc = jnp.moveaxis(cum, 1, 0)              # (nc,B,Q,G,r)

    ii = jnp.arange(Q)
    tri = (ii[:, None] >= ii[None, :])

    def step(state, inp):
        bc, cc, xb, lc = inp              # per-chunk tensors
        # intra-chunk: per-group scores, per-head decay gates
        sg = pdot("bign,bjgn->bgij", cc, bc, pol)            # (B,G,Q,Q)
        dgate = lc.transpose(0, 2, 3, 1)                     # (B,G,r,Q)
        decay = jnp.exp(jnp.clip(dgate[..., :, None] - dgate[..., None, :],
                                 -60.0, 0.0))
        gate = jnp.where(tri, decay, 0.0)                    # (B,G,r,Q,Q)
        y_intra = pdot("bgrij,bjgrp->bigrp", sg[:, :, None] * gate, xb, pol)
        # inter-chunk: contribution of the carried state
        hdecay = jnp.exp(lc)                                 # (B,Q,G,r)
        y_inter = pdot("bqgn,bgrnp->bqgrp", cc, state, pol) \
            * hdecay[..., None]
        # new state: decayed old + sum_j B_j (x) (xbar_j * tail_j)
        tail = jnp.exp(lc[:, -1:, :, :] - lc)                # (B,Q,G,r)
        cstate = pdot("bqgn,bqgrp->bgrnp", bc, xb * tail[..., None], pol)
        tot = jnp.exp(lc[:, -1])                             # (B,G,r)
        new_state = state * tot[..., None, None] + cstate
        return new_state, y_intra + y_inter

    init = jnp.zeros((B, G, rep, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, init, (Bc, Cc, Xc, Lc))        # (nc,B,Q,G,r,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + xs.reshape(B, S, H, P) * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return pdot("bse,ed->bsd", y, p["w_out"], cfg.policy)


def ssd_init_cache(cfg, batch: int):
    d_inner, H = ssd_dims(cfg)
    G, N, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    K = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((batch, K, d_inner), jnp.float32),
        "conv_b": jnp.zeros((batch, K, G * N), jnp.float32),
        "conv_c": jnp.zeros((batch, K, G * N), jnp.float32),
        "state": jnp.zeros((batch, G, H // G, N, P), jnp.float32),
    }


def _conv_step(cache, xt, w, b):
    """One causal-conv step against a rolling window cache. xt: (B, 1, C)."""
    window = jnp.concatenate([cache, xt], axis=1)            # (B, K, C)
    out = (window * w[None]).sum(axis=1) + b
    return jax.nn.silu(out)[:, None, :], window[:, 1:]


def ssd_decode(p, x, cfg, cache):
    """Single-token recurrent step. x: (B, 1, D)."""
    B = x.shape[0]
    d_inner, H = ssd_dims(cfg)
    G, N, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    rep = H // G

    z, xs, Bm, Cm, dt = _project(p, x, cfg)
    xs, ncx = _conv_step(cache["conv_x"], xs, p["conv_x"], p["conv_bias_x"])
    Bm, ncb = _conv_step(cache["conv_b"], Bm, p["conv_b"], p["conv_bias_b"])
    Cm, ncc = _conv_step(cache["conv_c"], Cm, p["conv_c"], p["conv_bias_c"])

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dA = jnp.exp(dts * A).reshape(B, G, rep)
    xh = (xs[:, 0].reshape(B, G, rep, P)
          * dts.reshape(B, G, rep)[..., None])                   # xbar
    Bh = Bm[:, 0].reshape(B, G, N)
    Ch = Cm[:, 0].reshape(B, G, N)
    state = cache["state"] * dA[..., None, None] + \
        Bh[:, :, None, :, None] * xh[:, :, :, None, :]
    y = jnp.einsum("bgn,bgrnp->bgrp", Ch, state)
    y = y + xs[:, 0].reshape(B, G, rep, P) \
        * p["D_skip"].reshape(G, rep)[None, :, :, None]
    y = y.reshape(B, 1, d_inner) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = pdot("bse,ed->bsd", y, p["w_out"], cfg.policy)
    new_cache = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "state": state}
    return out, new_cache


def ssd_reference(p, x, cfg):
    """Naive sequential recurrence — oracle for the chunked path."""
    B, S, D = x.shape
    cache = ssd_init_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = ssd_decode(p, x[:, t:t + 1], cfg, cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
