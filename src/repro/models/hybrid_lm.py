"""Zamba2-style hybrid: Mamba-2 backbone with a *shared* (weight-tied)
attention+MLP block applied every ``cfg.attn_every`` layers on
concat(hidden, original embedding) — the Zamba parameter-reuse trick."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pdot
from . import layers as L
from . import ssd
from .lm import cross_entropy, embed, unembed_logits
from .modules import dense_init, embed_init, split_keys, stack_init, zeros


def _mamba_layer_init(key, cfg):
    return {"ln": zeros((cfg.d_model,)), "ssd": ssd.ssd_init(key, cfg)}


def _shared_block_init(key, cfg):
    D = cfg.d_model
    ks = split_keys(key, 4)
    return {
        "w_cat": dense_init(ks[0], (2 * D, D), fan_in=2 * D),
        "ln1": zeros((D,)),
        "attn": L.attn_init(ks[1], cfg),
        "ln2": zeros((D,)),
        "mlp": L.mlp_init(ks[2], cfg),
        "w_out": dense_init(ks[3], (D, D), fan_in=D),
    }


def group_sizes(cfg):
    """Layer groups: shared attn block applied after each full group."""
    n, g = cfg.n_layers, cfg.attn_every
    sizes = [g] * (n // g)
    if n % g:
        sizes.append(n % g)
    n_apps = n // g
    return sizes, n_apps


def init(cfg, key):
    ks = split_keys(key, 4)
    params = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
        "blocks": stack_init(lambda k: _mamba_layer_init(k, cfg), ks[1],
                             cfg.n_layers),
        "shared": _shared_block_init(ks[2], cfg),
        "ln_f": zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[3], (cfg.d_model, cfg.padded_vocab),
                                       fan_in=cfg.d_model)
    return params


def _shared_apply(sp, x, emb, cfg, positions):
    u = pdot("bsd,de->bse", jnp.concatenate([x, emb], axis=-1),
             sp["w_cat"], cfg.policy)
    h = L.rmsnorm(sp["ln1"], u, cfg.norm_eps)
    u = u + L.attention(sp["attn"], h, cfg, positions, causal=True)
    h = L.rmsnorm(sp["ln2"], u, cfg.norm_eps)
    u = u + L.mlp(sp["mlp"], h, cfg)
    return x + pdot("bsd,de->bse", u, sp["w_out"], cfg.policy)


def _shared_decode(sp, x, emb, cfg, cache, cache_index):
    u = pdot("bsd,de->bse", jnp.concatenate([x, emb], axis=-1),
             sp["w_cat"], cfg.policy)
    h = L.rmsnorm(sp["ln1"], u, cfg.norm_eps)
    a, new_cache = L.attention_decode(sp["attn"], h, cfg, cache, cache_index)
    u = u + a
    h = L.rmsnorm(sp["ln2"], u, cfg.norm_eps)
    u = u + L.mlp(sp["mlp"], h, cfg)
    return x + pdot("bsd,de->bse", u, sp["w_out"], cfg.policy), new_cache


def backbone(params, tokens, cfg):
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    emb = embed(params, tokens, cfg)
    x = emb
    sizes, n_apps = group_sizes(cfg)

    def body(carry, lp):
        h = L.rmsnorm(lp["ln"], carry, cfg.norm_eps)
        return carry + ssd.ssd_layer(lp["ssd"], h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    off = 0
    for gi, gs in enumerate(sizes):
        grp = jax.tree.map(lambda a: a[off:off + gs], params["blocks"])
        x, _ = jax.lax.scan(body, x, grp)
        off += gs
        if gi < n_apps:
            x = _shared_apply(params["shared"], x, emb, cfg, positions)
    return L.rmsnorm(params["ln_f"], x, cfg.norm_eps)


def loss_fn(params, batch, cfg):
    x = backbone(params, batch["tokens"], cfg)
    logits = unembed_logits(params, x, cfg)
    loss, denom = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss, "lm_loss": loss, "tokens": denom}


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    _, n_apps = group_sizes(cfg)
    mamba = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(),
        ssd.ssd_init_cache(cfg, batch))
    kv = {"k": jnp.zeros((n_apps, batch, max_len, cfg.n_kv_heads,
                          cfg.head_dim), dtype),
          "v": jnp.zeros((n_apps, batch, max_len, cfg.n_kv_heads,
                          cfg.head_dim), dtype)}
    return {"mamba": mamba, "shared_kv": kv}


def decode_step(params, cfg, cache, tokens, cache_index):
    x = embed(params, tokens[:, None], cfg)
    emb = x
    sizes, n_apps = group_sizes(cfg)

    def body(carry, xs):
        lp, c = xs
        h = L.rmsnorm(lp["ln"], carry, cfg.norm_eps)
        o, nc = ssd.ssd_decode(lp["ssd"], h, cfg, c)
        return carry + o, nc

    new_mamba, new_kv = [], []
    off = 0
    for gi, gs in enumerate(sizes):
        grp = jax.tree.map(lambda a: a[off:off + gs], params["blocks"])
        cgrp = jax.tree.map(lambda a: a[off:off + gs], cache["mamba"])
        x, nc = jax.lax.scan(body, x, (grp, cgrp))
        new_mamba.append(nc)
        off += gs
        if gi < n_apps:
            kv = jax.tree.map(lambda a: a[gi], cache["shared_kv"])
            x, nkv = _shared_decode(params["shared"], x, emb, cfg, kv,
                                    cache_index)
            new_kv.append(nkv)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_logits(params, x, cfg)
    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
        "shared_kv": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_kv),
    }
    return logits[:, 0], new_cache


def forward_logits(params, batch, cfg):
    """Prefill entry: logits only (serving-side forward)."""
    return unembed_logits(params, backbone(params, batch["tokens"], cfg), cfg)
