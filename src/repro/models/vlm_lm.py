"""InternVL2-style VLM. The vision tower is a STUB per the assignment:
``batch["patches"]`` carries precomputed patch embeddings (InternViT
features); the MLP projector and the InternLM2-style language backbone are
real, and the LM loss is masked to text positions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pdot
from . import layers as L
from .lm import (cross_entropy, embed, init as lm_init, layer_windows,
                 stack_apply, unembed_logits, init_cache as lm_init_cache,
                 decode_step as lm_decode_step)
from .modules import dense_init, split_keys


def init(cfg, key):
    params = lm_init(cfg, jax.random.fold_in(key, 0))
    ks = split_keys(jax.random.fold_in(key, 1), 2)
    params["projector"] = {
        "w1": dense_init(ks[0], (cfg.frontend_dim, cfg.d_model),
                         fan_in=cfg.frontend_dim),
        "w2": dense_init(ks[1], (cfg.d_model, cfg.d_model),
                         fan_in=cfg.d_model),
    }
    return params


def project_patches(params, patches, cfg):
    h = pdot("bpf,fd->bpd", patches.astype(jnp.float32),
             params["projector"]["w1"], cfg.policy)
    h = jax.nn.gelu(h)
    return pdot("bpd,de->bpe", h, params["projector"]["w2"], cfg.policy)


def forward_logits(params, batch, cfg):
    """batch: patches (B, P, frontend_dim), tokens (B, S_text)."""
    vis = project_patches(params, batch["patches"], cfg)
    txt = embed(params, batch["tokens"], cfg)
    x = jnp.concatenate([vis, txt], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = layer_windows(cfg, cfg.n_layers)
    x, _ = stack_apply(params["dense_blocks"], x, cfg, positions, windows,
                       moe=False)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return unembed_logits(params, x, cfg)


def loss_fn(params, batch, cfg):
    """labels: (B, P + S_text) with -1 on patch positions."""
    logits = forward_logits(params, batch, cfg)
    loss, denom = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss, "lm_loss": loss, "tokens": denom}


# decode is standard LM decode over the combined sequence (image prefilled)
init_cache = lm_init_cache
decode_step = lm_decode_step
