"""Mamba-2 language model (SSD backbone, attention-free)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssd
from .lm import cross_entropy, embed, unembed_logits
from .modules import dense_init, embed_init, split_keys, stack_init, zeros


def _layer_init(key, cfg):
    return {"ln": zeros((cfg.d_model,)), "ssd": ssd.ssd_init(key, cfg)}


def init(cfg, key):
    ks = split_keys(key, 3)
    params = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
        "blocks": stack_init(lambda k: _layer_init(k, cfg), ks[1],
                             cfg.n_layers),
        "ln_f": zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[2], (cfg.d_model, cfg.padded_vocab),
                                       fan_in=cfg.d_model)
    return params


def backbone(params, tokens, cfg):
    x = embed(params, tokens, cfg)

    def body(carry, lp):
        h = L.rmsnorm(lp["ln"], carry, cfg.norm_eps)
        return carry + ssd.ssd_layer(lp["ssd"], h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.rmsnorm(params["ln_f"], x, cfg.norm_eps)


def loss_fn(params, batch, cfg):
    x = backbone(params, batch["tokens"], cfg)
    logits = unembed_logits(params, x, cfg)
    loss, denom = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss, "lm_loss": loss, "tokens": denom}


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    one = ssd.ssd_init_cache(cfg, batch)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)


def decode_step(params, cfg, cache, tokens, cache_index):
    x = embed(params, tokens[:, None], cfg)

    def body(carry, xs):
        lp, c = xs
        h = L.rmsnorm(lp["ln"], carry, cfg.norm_eps)
        o, nc = ssd.ssd_decode(lp["ssd"], h, cfg, c)
        return carry + o, nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_logits(params, x, cfg)
    return logits[:, 0], new_cache


def forward_logits(params, batch, cfg):
    """Prefill entry: logits only (serving-side forward)."""
    return unembed_logits(params, backbone(params, batch["tokens"], cfg), cfg)
