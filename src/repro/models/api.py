"""Family-dispatched model API: init / loss_fn / init_cache / decode_step.

Every family exposes the same four entry points, so the trainer, server,
dry-run, and benchmarks are family-agnostic."""
from __future__ import annotations

from types import SimpleNamespace

from . import encdec_lm, hybrid_lm, lm, ssm_lm, vlm_lm

_FAMILIES = {
    "dense": lm,
    "moe": lm,
    "ssm": ssm_lm,
    "hybrid": hybrid_lm,
    "audio": encdec_lm,
    "vlm": vlm_lm,
}


def get_model(cfg) -> SimpleNamespace:
    mod = _FAMILIES[cfg.family]
    return SimpleNamespace(
        init=lambda key: mod.init(cfg, key),
        loss_fn=lambda params, batch: mod.loss_fn(params, batch, cfg),
        forward_logits=lambda params, batch: mod.forward_logits(
            params, batch, cfg),
        init_cache=lambda batch, max_len, **kw: mod.init_cache(
            cfg, batch, max_len, **kw),
        decode_step=lambda params, cache, tokens, idx: mod.decode_step(
            params, cfg, cache, tokens, idx),
        module=mod,
    )
