"""Family-dispatched model API: init / loss_fn / init_cache / decode_step.

Every family exposes the same four entry points, so the trainer, server,
dry-run, and benchmarks are family-agnostic."""
from __future__ import annotations

from types import SimpleNamespace

from . import encdec_lm, hybrid_lm, lm, ssm_lm, vlm_lm

_FAMILIES = {
    "dense": lm,
    "moe": lm,
    "ssm": ssm_lm,
    "hybrid": hybrid_lm,
    "audio": encdec_lm,
    "vlm": vlm_lm,
}


def get_model(cfg) -> SimpleNamespace:
    mod = _FAMILIES[cfg.family]
    # Paged serving entries exist only for the KV-cache families (lm.py:
    # dense/moe, incl. MLA); the continuous-batching engine checks for
    # None and the serve CLI falls back to the dense loop elsewhere.
    paged = hasattr(mod, "decode_step_paged")
    return SimpleNamespace(
        init=lambda key: mod.init(cfg, key),
        loss_fn=lambda params, batch: mod.loss_fn(params, batch, cfg),
        forward_logits=lambda params, batch: mod.forward_logits(
            params, batch, cfg),
        init_cache=lambda batch, max_len, **kw: mod.init_cache(
            cfg, batch, max_len, **kw),
        decode_step=lambda params, cache, tokens, idx: mod.decode_step(
            params, cfg, cache, tokens, idx),
        prefill=(lambda params, tokens, positions=None: mod.prefill(
            params, cfg, tokens, positions)) if paged else None,
        init_paged_cache=(lambda num_pages, page_size, **kw:
                          mod.init_paged_cache(cfg, num_pages, page_size,
                                               **kw)) if paged else None,
        decode_step_paged=(lambda params, pools, block_tables, lengths,
                           tokens: mod.decode_step_paged(
                               params, cfg, pools, block_tables, lengths,
                               tokens)) if paged else None,
        module=mod,
    )
