"""Family-dispatched model API: init / loss_fn / init_cache / decode_step.

Every family exposes the same four entry points, so the trainer, server,
dry-run, and benchmarks are family-agnostic.  ``get_model`` optionally
pins a :class:`repro.numerics.NumericsConfig` scope around every entry
point, so a model handle can carry its kernel-dispatch recipe with it
(the serving engine snapshots its own config the same way)."""
from __future__ import annotations

import functools
from types import SimpleNamespace

from repro import numerics
from . import encdec_lm, hybrid_lm, lm, ssm_lm, vlm_lm

_FAMILIES = {
    "dense": lm,
    "moe": lm,
    "ssm": ssm_lm,
    "hybrid": hybrid_lm,
    "audio": encdec_lm,
    "vlm": vlm_lm,
}


def _pinned(fn, cfg: numerics.NumericsConfig):
    if fn is None:
        return None

    @functools.wraps(fn)
    def wrapped(*a, **kw):
        with numerics.use(cfg):
            return fn(*a, **kw)

    return wrapped


def get_model(cfg, numerics_config: numerics.NumericsConfig | None = None
              ) -> SimpleNamespace:
    """Build the family-agnostic model handle for ``cfg``.

    ``numerics_config`` (optional) pins every entry point to that numerics
    scope — equivalent to wrapping each call in ``repro.numerics.use(...)``
    — so dispatch decisions stay stable regardless of the caller's ambient
    context.
    """
    mod = _FAMILIES[cfg.family]
    # Paged serving entries exist only for the KV-cache families (lm.py:
    # dense/moe, incl. MLA); the continuous-batching engine checks for
    # None and the serve CLI falls back to the dense loop elsewhere.
    paged = hasattr(mod, "decode_step_paged")
    handle = SimpleNamespace(
        init=lambda key: mod.init(cfg, key),
        loss_fn=lambda params, batch: mod.loss_fn(params, batch, cfg),
        forward_logits=lambda params, batch: mod.forward_logits(
            params, batch, cfg),
        init_cache=lambda batch, max_len, **kw: mod.init_cache(
            cfg, batch, max_len, **kw),
        decode_step=lambda params, cache, tokens, idx: mod.decode_step(
            params, cfg, cache, tokens, idx),
        prefill=(lambda params, tokens, positions=None: mod.prefill(
            params, cfg, tokens, positions)) if paged else None,
        prefill_chunk=(lambda params, cache, tokens, start:
                       mod.prefill_chunk(params, cfg, cache, tokens,
                                         start)) if paged else None,
        init_paged_cache=(lambda num_pages, page_size, **kw:
                          mod.init_paged_cache(cfg, num_pages, page_size,
                                               **kw)) if paged else None,
        decode_step_paged=(lambda params, pools, block_tables, lengths,
                           tokens: mod.decode_step_paged(
                               params, cfg, pools, block_tables, lengths,
                               tokens)) if paged else None,
        module=mod,
    )
    if numerics_config is not None:
        for name in ("init", "loss_fn", "forward_logits", "init_cache",
                     "decode_step", "prefill", "prefill_chunk",
                     "init_paged_cache", "decode_step_paged"):
            setattr(handle, name, _pinned(getattr(handle, name),
                                          numerics_config))
    return handle
