"""Model zoo: dense/MoE/SSM/hybrid/enc-dec/VLM families, all routing every
contraction through the paper's TCEC precision policy."""
from .api import get_model

__all__ = ["get_model"]
