"""Encoder-decoder transformer (Seamless-M4T style). The speech frontend is
a STUB per the assignment: ``batch["frames"]`` carries precomputed frame
embeddings; the encoder, decoder, and cross-attention are real."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pdot
from . import layers as L
from .lm import cross_entropy, embed, unembed_logits
from .modules import dense_init, embed_init, split_keys, stack_init, zeros


def _xattn_init(key, cfg):
    return L.attn_init(key, cfg)


def _enc_layer_init(key, cfg):
    ks = split_keys(key, 2)
    return {"ln1": zeros((cfg.d_model,)), "attn": L.attn_init(ks[0], cfg),
            "ln2": zeros((cfg.d_model,)), "mlp": L.mlp_init(ks[1], cfg)}


def _dec_layer_init(key, cfg):
    ks = split_keys(key, 3)
    return {"ln1": zeros((cfg.d_model,)), "attn": L.attn_init(ks[0], cfg),
            "lnx": zeros((cfg.d_model,)), "xattn": _xattn_init(ks[1], cfg),
            "ln2": zeros((cfg.d_model,)), "mlp": L.mlp_init(ks[2], cfg)}


def init(cfg, key):
    ks = split_keys(key, 5)
    return {
        "frontend_proj": dense_init(ks[0], (cfg.frontend_dim, cfg.d_model),
                                    fan_in=cfg.frontend_dim),
        "enc_blocks": stack_init(lambda k: _enc_layer_init(k, cfg), ks[1],
                                 cfg.n_enc_layers),
        "enc_ln_f": zeros((cfg.d_model,)),
        "embed": embed_init(ks[2], (cfg.padded_vocab, cfg.d_model)),
        "dec_blocks": stack_init(lambda k: _dec_layer_init(k, cfg), ks[3],
                                 cfg.n_layers),
        "ln_f": zeros((cfg.d_model,)),
        "unembed": dense_init(ks[4], (cfg.d_model, cfg.padded_vocab),
                              fan_in=cfg.d_model),
    }


def _cross_attention(p, x, mem_k, mem_v, cfg):
    """Cross-attention; q from decoder, K/V precomputed from encoder memory.
    Context-parallel like self-attention: q-sequence shards on model.

    Routed through the shared ``layers.sdpa`` (fused kernel when dispatch
    allows, pdot composition else) with a softcap-free cfg shim — decoder
    softcaps never applied to cross-attention here, and the unmasked
    non-causal case is exactly ``mha`` with an all-zero mask bias."""
    import types
    q = pdot("bsd,dhk->bshk", x, p["wq"], cfg.policy)
    S, T = q.shape[1], mem_k.shape[1]
    shim = types.SimpleNamespace(mix_policy=cfg.mix_policy, attn_softcap=None)
    o = L.sdpa(q, mem_k, mem_v, shim,
               jnp.arange(S, dtype=jnp.int32)[None],
               jnp.arange(T, dtype=jnp.int32)[None],
               causal=False, window=0)
    return pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)


def _mem_kv(p, mem, cfg):
    k = pdot("bsd,dhk->bshk", mem, p["wk"], cfg.policy)
    v = pdot("bsd,dhk->bshk", mem, p["wv"], cfg.policy)
    return k, v


def encode(params, frames, cfg):
    x = pdot("bsf,fd->bsd", frames.astype(jnp.float32),
             params["frontend_proj"], cfg.policy)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, lp):
        h = L.rmsnorm(lp["ln1"], carry, cfg.norm_eps)
        x1 = carry + L.attention(lp["attn"], h, cfg, positions, causal=False)
        h = L.rmsnorm(lp["ln2"], x1, cfg.norm_eps)
        return x1 + L.mlp(lp["mlp"], h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_ln_f"], x, cfg.norm_eps)


def decode_train(params, tokens, mem, cfg):
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed(params, tokens, cfg)

    def body(carry, lp):
        h = L.rmsnorm(lp["ln1"], carry, cfg.norm_eps)
        x1 = carry + L.attention(lp["attn"], h, cfg, positions, causal=True)
        h = L.rmsnorm(lp["lnx"], x1, cfg.norm_eps)
        mk, mv = _mem_kv(lp["xattn"], mem, cfg)
        x2 = x1 + _cross_attention(lp["xattn"], h, mk, mv, cfg)
        h = L.rmsnorm(lp["ln2"], x2, cfg.norm_eps)
        return x2 + L.mlp(lp["mlp"], h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return L.rmsnorm(params["ln_f"], x, cfg.norm_eps)


def loss_fn(params, batch, cfg):
    mem = encode(params, batch["frames"], cfg)
    x = decode_train(params, batch["tokens"], mem, cfg)
    logits = unembed_logits(params, x, cfg)
    loss, denom = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss, "lm_loss": loss, "tokens": denom}


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               mem_len: int | None = None):
    """Self KV per decoder layer + precomputed cross K/V over the memory."""
    mem_len = mem_len or max(max_len // 8, 64)
    kv = lambda T: {  # noqa: E731
        "k": jnp.zeros((cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.head_dim),
                       dtype)}
    return {"self": kv(max_len), "cross": kv(mem_len)}


def prefill_cross(params, frames, cfg, cache):
    """Run the encoder once and fill the cross-attention K/V cache."""
    mem = encode(params, frames, cfg)

    def body(_, lp):
        mk, mv = _mem_kv(lp["xattn"], mem, cfg)
        return None, {"k": mk.astype(jnp.bfloat16),
                      "v": mv.astype(jnp.bfloat16)}

    _, cross = jax.lax.scan(body, None, params["dec_blocks"])
    return {"self": cache["self"], "cross": cross}


def decode_step(params, cfg, cache, tokens, cache_index):
    x = embed(params, tokens[:, None], cfg)

    def body(carry, xs):
        lp, selfc, crossc = xs
        h = L.rmsnorm(lp["ln1"], carry, cfg.norm_eps)
        a, nself = L.attention_decode(lp["attn"], h, cfg, selfc, cache_index)
        x1 = carry + a
        h = L.rmsnorm(lp["lnx"], x1, cfg.norm_eps)
        x2 = x1 + _cross_attention(lp["xattn"], h,
                                   crossc["k"].astype(jnp.float32),
                                   crossc["v"].astype(jnp.float32), cfg)
        h = L.rmsnorm(lp["ln2"], x2, cfg.norm_eps)
        return x2 + L.mlp(lp["mlp"], h, cfg), nself

    x, nself = jax.lax.scan(body, x, (params["dec_blocks"], cache["self"],
                                      cache["cross"]))
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_logits(params, x, cfg)
    return logits[:, 0], {"self": nself, "cross": cache["cross"]}


def forward_logits(params, batch, cfg):
    """Prefill entry: logits only (serving-side forward)."""
    mem = encode(params, batch["frames"], cfg)
    x = decode_train(params, batch["tokens"], mem, cfg)
    return unembed_logits(params, x, cfg)
