"""Minimal pytree parameter helpers (no flax — params are nested dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, fan_in: int | None = None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * 0.02).astype(dtype)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype=dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype=dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


def stack_init(init_fn, key, n: int):
    """Initialize ``n`` layer param trees and stack leaves on a leading dim
    (the scan-over-layers layout: O(1) HLO size for any depth)."""
    trees = [init_fn(k) for k in jax.random.split(key, n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
