"""Multi-head Latent Attention (DeepSeek-V2/V3) with low-rank Q/KV
compression, decoupled RoPE keys, and compressed-cache decode (the
"absorb" formulation) — the KV cache stores only (c_kv, k_rope)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pdot
from .modules import dense_init, split_keys, zeros
from .layers import rmsnorm, rope, sdpa, NEG_INF


def mla_init(key, cfg):
    D, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = split_keys(key, 8)
    return {
        "w_dq": dense_init(ks[0], (D, qr), fan_in=D),
        "q_norm": zeros((qr,)),
        "w_uq": dense_init(ks[1], (qr, H, dn + dr), fan_in=qr),
        "w_dkv": dense_init(ks[2], (D, kvr), fan_in=D),
        "kv_norm": zeros((kvr,)),
        "w_uk": dense_init(ks[3], (kvr, H, dn), fan_in=kvr),
        "w_uv": dense_init(ks[4], (kvr, H, dv), fan_in=kvr),
        "w_kr": dense_init(ks[5], (D, dr), fan_in=D),
        "wo": dense_init(ks[6], (H, dv, D), fan_in=H * dv),
    }


def _q_proj(p, x, cfg, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(p["q_norm"], pdot("bsd,dr->bsr", x, p["w_dq"], cfg.policy),
                 cfg.norm_eps)
    q = pdot("bsr,rhk->bshk", cq, p["w_uq"], cfg.policy)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _kv_compress(p, x, cfg, positions):
    c_kv = rmsnorm(p["kv_norm"],
                   pdot("bsd,dr->bsr", x, p["w_dkv"], cfg.policy),
                   cfg.norm_eps)
    k_rope = pdot("bsd,dk->bsk", x, p["w_kr"], cfg.policy)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, x, cfg, positions):
    """Prefill/train path: decompress K/V, run (blocked) attention."""
    out, _ = mla_attention_prefill(p, x, cfg, positions)
    return out


def mla_attention_prefill(p, x, cfg, positions):
    """:func:`mla_attention` that also returns the compressed cache
    entries ``(c_kv, k_rope)`` it computed, so a sequence-level prefill
    fills the latent cache in one jitted forward."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    c_kv, k_rope = _kv_compress(p, x, cfg, positions)
    k_nope = pdot("bsr,rhk->bshk", c_kv, p["w_uk"], cfg.policy)
    v = pdot("bsr,rhk->bshk", c_kv, p["w_uv"], cfg.policy)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))],
        axis=-1)
    # sdpa routes to the fused TCEC attention kernel when dispatch allows
    # (hd = nope+rope and hdv = v_head_dim differ; the kernel supports that)
    o = sdpa(q, k, v, cfg, positions, positions, causal=True)
    out = pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_attention_chunk(p, x, cfg, cache, start):
    """One prefill chunk against a dense latent scratch cache.

    x: (B, C, D) at absolute positions ``start .. start + C``; cache:
    :func:`mla_init_cache` leaves (B, T, kvr)/(B, T, dr) holding earlier
    chunks' exact compressed entries.  Takes the *decompressed* attend —
    the same math as :func:`mla_attention_prefill`, NOT the absorbed
    decode path — so chunk rows match the monolithic prefill bitwise when
    the scratch is f32 (decompression is per-position, so cached prefix
    rows decompress to exactly the monolithic values).
    """
    B, C = x.shape[:2]
    H, dr = cfg.n_heads, cfg.qk_rope_dim
    positions = jnp.broadcast_to(
        start + jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    c_kv_t, k_rope_t = _kv_compress(p, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_t.astype(cache["c_kv"].dtype), (0, start, 0))
    kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype),
        (0, start, 0))
    T = ck.shape[1]
    k_nope = pdot("bsr,rhk->bshk", ck, p["w_uk"], cfg.policy)
    v = pdot("bsr,rhk->bshk", ck, p["w_uv"], cfg.policy)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, T, H, dr))],
        axis=-1)
    k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    o = sdpa(q, k, v, cfg, positions, k_pos, causal=True)
    out = pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)
    return out, {"c_kv": ck, "k_rope": kr}


def mla_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def _mla_attend(p, q_c, q_rope, ck, kr, cfg, cur_pos):
    """Absorbed-space attend over a dense-layout latent cache view.

    q_c: (B, 1, H, kvr); q_rope: (B, 1, H, dr); ck/kr: (B, T, kvr)/(B, T,
    dr) — the dense latent cache or a page gather.  ``cur_pos`` is the
    current token's position: scalar (dense decode) or (B,) vector
    (continuous batching).  bf16 cache dots: no f32 cache copies."""
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    s_c = pdot("bshr,btr->bhst", q_c, ck, "bf16")
    s_r = pdot("bshk,btk->bhst", q_rope, kr, "bf16")
    s = (s_c + s_r) / np.sqrt(dn + dr)
    T = ck.shape[1]
    cur = jnp.asarray(cur_pos, jnp.int32).reshape(-1, 1)      # (B or 1, 1)
    valid = jnp.arange(T, dtype=jnp.int32)[None] <= cur       # (B or 1, T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    ctx = pdot("bhst,btr->bshr", pr, ck, "bf16")
    o = pdot("bshr,rhk->bshk", ctx, p["w_uv"], cfg.policy)    # (B,1,H,dv)
    return pdot("bshk,hkd->bsd", o, p["wo"], cfg.policy)


def mla_decode(p, x, cfg, cache, cache_index):
    """Absorbed decode: attention runs in the compressed (kv_lora) space;
    cache traffic is (kv_lora + rope_dim) per token instead of 2*H*d."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cache_index, dtype=jnp.int32)
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    c_kv_t, k_rope_t = _kv_compress(p, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_t.astype(cache["c_kv"].dtype), (0, cache_index, 0))
    kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype),
        (0, cache_index, 0))
    # absorb W_uk into the query: q_c = q_nope @ W_uk  -> compressed space
    q_c = pdot("bshk,rhk->bshr", q_nope, p["w_uk"], cfg.policy)  # (B,1,H,kvr)
    out = _mla_attend(p, q_c, q_rope, ck, kr, cfg, cache_index)
    return out, {"c_kv": ck, "k_rope": kr}


def mla_decode_paged(p, x, cfg, pool, block_tables, lengths):
    """Absorbed decode against a paged latent cache (serving engine).

    pool: ``{"c_kv": (NP, ps, kvr), "k_rope": (NP, ps, dr)}`` page arrays;
    block_tables: (B, maxp) i32; lengths: (B,) i32 tokens already cached
    (the current token's position).  The compressed cache is already the
    bandwidth-optimal layout, and the absorbed attend is a rank-space
    contraction the standard-layout paged kernel cannot express — so MLA
    always takes the page-gather + :func:`_mla_attend` path (bitwise the
    dense ``mla_decode`` math; ``dispatch.attention_decode`` declines the
    latent shapes anyway)."""
    B = x.shape[0]
    positions = lengths[:, None].astype(jnp.int32)
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    c_kv_t, k_rope_t = _kv_compress(p, x, cfg, positions)
    ps = pool["c_kv"].shape[1]
    maxp = block_tables.shape[1]
    page = block_tables[jnp.arange(B), lengths // ps]
    off = lengths % ps
    ck = pool["c_kv"].at[page, off].set(
        c_kv_t[:, 0].astype(pool["c_kv"].dtype))
    kr = pool["k_rope"].at[page, off].set(
        k_rope_t[:, 0].astype(pool["k_rope"].dtype))
    q_c = pdot("bshk,rhk->bshr", q_nope, p["w_uk"], cfg.policy)
    ckg = ck[block_tables].reshape(B, maxp * ps, ck.shape[-1])
    krg = kr[block_tables].reshape(B, maxp * ps, kr.shape[-1])
    out = _mla_attend(p, q_c, q_rope, ckg, krg, cfg, lengths)
    return out, {"c_kv": ck, "k_rope": kr}
