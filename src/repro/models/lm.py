"""Decoder-only LM assembly: dense (gemma/qwen), MoE (granite/deepseek),
with MLA and local/global attention variants. Layers are stacked with
``lax.scan`` over stacked params (O(1) HLO size at any depth) and
rematerialized per block."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pdot
from . import layers as L
from . import mla as M
from .modules import dense_init, embed_init, split_keys, stack_init, zeros


# --------------------------------------------------------------- blocks

def block_init(key, cfg, *, moe: bool):
    ks = split_keys(key, 4)
    p = {"ln1": zeros((cfg.d_model,)), "ln2": zeros((cfg.d_model,))}
    if cfg.use_mla:
        p["attn"] = M.mla_init(ks[0], cfg)
    else:
        p["attn"] = L.attn_init(ks[0], cfg)
    if moe:
        p["moe"] = L.moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg)
    if cfg.sandwich_norms:
        p["post_ln1"] = zeros((cfg.d_model,))
        p["post_ln2"] = zeros((cfg.d_model,))
    return p


def block_apply(p, x, cfg, positions, window, *, moe: bool):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a = M.mla_attention(p["attn"], h, cfg, positions)
    else:
        a = L.attention(p["attn"], h, cfg, positions, causal=True,
                        window=window)
    if cfg.sandwich_norms:
        a = L.rmsnorm(p["post_ln1"], a, cfg.norm_eps)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.float32(0.0)
    if moe:
        m, aux = L.moe(p["moe"], h, cfg)
    else:
        m = L.mlp(p["mlp"], h, cfg)
    if cfg.sandwich_norms:
        m = L.rmsnorm(p["post_ln2"], m, cfg.norm_eps)
    return x + m, aux


def block_prefill(p, x, cfg, positions, window, *, moe: bool):
    """``block_apply`` that also returns the block's cache entries, so a
    sequence-level prefill fills the KV cache in one jitted forward."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, kv = M.mla_attention_prefill(p["attn"], h, cfg, positions)
    else:
        a, kv = L.attention_prefill(p["attn"], h, cfg, positions,
                                    window=window)
    if cfg.sandwich_norms:
        a = L.rmsnorm(p["post_ln1"], a, cfg.norm_eps)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.float32(0.0)
    if moe:
        m, aux = L.moe(p["moe"], h, cfg)
    else:
        m = L.mlp(p["mlp"], h, cfg)
    if cfg.sandwich_norms:
        m = L.rmsnorm(p["post_ln2"], m, cfg.norm_eps)
    return x + m, aux, kv


def block_decode(p, x, cfg, cache, cache_index, window, *, moe: bool):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = M.mla_decode(p["attn"], h, cfg, cache, cache_index)
    else:
        a, new_cache = L.attention_decode(p["attn"], h, cfg, cache,
                                          cache_index, window=window)
    if cfg.sandwich_norms:
        a = L.rmsnorm(p["post_ln1"], a, cfg.norm_eps)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        m, _ = L.moe(p["moe"], h, cfg)
    else:
        m = L.mlp(p["mlp"], h, cfg)
    if cfg.sandwich_norms:
        m = L.rmsnorm(p["post_ln2"], m, cfg.norm_eps)
    return x + m, new_cache


# ------------------------------------------------------------ stacking

def layer_windows(cfg, n_layers: int) -> np.ndarray:
    """Per-layer sliding windows (0 = global) — gemma2's local/global."""
    if cfg.local_global_period and cfg.sliding_window:
        return np.asarray(
            [cfg.sliding_window if i % cfg.local_global_period == 0 else 0
             for i in range(n_layers)], dtype=np.int32)
    if cfg.sliding_window:
        return np.full((n_layers,), cfg.sliding_window, dtype=np.int32)
    return np.zeros((n_layers,), dtype=np.int32)


def stack_apply(stacked, x, cfg, positions, windows, *, moe: bool):
    def body(carry, xs):
        lp, w = xs
        y, aux = block_apply(lp, carry, cfg, positions, w, moe=moe)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, (stacked, jnp.asarray(windows)))
    return x, jnp.sum(auxs)


def stack_prefill(stacked, x, cfg, positions, windows, *, moe: bool):
    """``stack_apply`` that stacks each layer's cache entries as scan ys:
    leaves come back as (n_layers, B, S, ...)."""
    def body(carry, xs):
        lp, w = xs
        y, aux, kv = block_prefill(lp, carry, cfg, positions, w, moe=moe)
        return y, (aux, kv)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (auxs, kvs) = jax.lax.scan(body, x, (stacked, jnp.asarray(windows)))
    return x, jnp.sum(auxs), kvs


def stack_decode(stacked, x, cfg, caches, cache_index, windows, *, moe: bool):
    def body(carry, xs):
        lp, cache, w = xs
        y, nc = block_decode(lp, carry, cfg, cache, cache_index, w, moe=moe)
        return y, nc

    x, new_caches = jax.lax.scan(body, x, (stacked, caches,
                                           jnp.asarray(windows)))
    return x, new_caches


def block_decode_paged(p, x, cfg, pool, block_tables, lengths, window, *,
                       moe: bool):
    """``block_decode`` against one layer's page pool (serving engine)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, new_pool = M.mla_decode_paged(p["attn"], h, cfg, pool,
                                         block_tables, lengths)
    else:
        a, new_pool = L.attention_decode_paged(p["attn"], h, cfg, pool,
                                               block_tables, lengths,
                                               window=window)
    if cfg.sandwich_norms:
        a = L.rmsnorm(p["post_ln1"], a, cfg.norm_eps)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        m, _ = L.moe(p["moe"], h, cfg)
    else:
        m = L.mlp(p["mlp"], h, cfg)
    if cfg.sandwich_norms:
        m = L.rmsnorm(p["post_ln2"], m, cfg.norm_eps)
    return x + m, new_pool


def stack_decode_paged(stacked, x, cfg, pools, block_tables, lengths,
                       windows, *, moe: bool):
    def body(carry, xs):
        lp, pool, w = xs
        y, npool = block_decode_paged(lp, carry, cfg, pool, block_tables,
                                      lengths, w, moe=moe)
        return y, npool

    x, new_pools = jax.lax.scan(body, x, (stacked, pools,
                                          jnp.asarray(windows)))
    return x, new_pools


def block_chunk(p, x, cfg, cache, start, window, *, moe: bool):
    """``block_prefill`` for one chunk of the prompt, reading/extending a
    dense scratch cache (chunked prefill — serving engine)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = M.mla_attention_chunk(p["attn"], h, cfg, cache, start)
    else:
        a, new_cache = L.attention_chunk(p["attn"], h, cfg, cache, start,
                                         window=window)
    if cfg.sandwich_norms:
        a = L.rmsnorm(p["post_ln1"], a, cfg.norm_eps)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        m, _ = L.moe(p["moe"], h, cfg)
    else:
        m = L.mlp(p["mlp"], h, cfg)
    if cfg.sandwich_norms:
        m = L.rmsnorm(p["post_ln2"], m, cfg.norm_eps)
    return x + m, new_cache


def stack_chunk(stacked, x, cfg, caches, start, windows, *, moe: bool):
    def body(carry, xs):
        lp, cache, w = xs
        y, nc = block_chunk(lp, carry, cfg, cache, start, w, moe=moe)
        return y, nc

    x, new_caches = jax.lax.scan(body, x, (stacked, caches,
                                           jnp.asarray(windows)))
    return x, new_caches


# ----------------------------------------------------------- top level

def init(cfg, key):
    ks = split_keys(key, 4)
    params = {"embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
              "ln_f": zeros((cfg.d_model,))}
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    if n_dense:
        params["dense_blocks"] = stack_init(
            lambda k: block_init(k, cfg, moe=False), ks[1], n_dense)
    if n_moe:
        params["moe_blocks"] = stack_init(
            lambda k: block_init(k, cfg, moe=True), ks[2], n_moe)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[3], (cfg.d_model, cfg.padded_vocab),
                                       fan_in=cfg.d_model)
    if cfg.mtp:
        params["mtp_block"] = block_init(
            jax.random.fold_in(key, 99), cfg,
            moe=bool(cfg.n_experts))
        params["mtp_proj"] = dense_init(
            jax.random.fold_in(key, 98), (2 * cfg.d_model, cfg.d_model),
            fan_in=2 * cfg.d_model)
    return params


def embed(params, tokens, cfg):
    from repro.parallel import ctx
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * np.sqrt(cfg.d_model)
    return ctx.constrain(x.astype(jnp.float32), ctx.dp_axes(), None, None)


def unembed_logits(params, x, cfg):
    from repro.parallel import ctx
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    pol = cfg.logits_policy or cfg.policy
    logits = pdot("bsd,dv->bsv", x, w, pol)
    logits = ctx.constrain(logits, ctx.dp_axes(), None, "model")
    return L.softcap(logits, cfg.final_softcap)


def backbone(params, tokens, cfg, positions):
    x = embed(params, tokens, cfg)
    aux = jnp.float32(0.0)
    windows = layer_windows(cfg, cfg.n_layers)
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    if n_dense:
        x, a = stack_apply(params["dense_blocks"], x, cfg, positions,
                           windows[:n_dense], moe=False)
        aux += a
    if n_moe:
        x, a = stack_apply(params["moe_blocks"], x, cfg, positions,
                           windows[n_dense:], moe=True)
        aux += a
    return L.rmsnorm(params["ln_f"], x, cfg.norm_eps), aux


def forward(params, batch, cfg):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, aux = backbone(params, tokens, cfg, positions)
    logits = unembed_logits(params, x, cfg)
    return logits, aux, x


def cross_entropy(logits, labels, z_loss_w: float = 1e-4):
    """Masked CE with z-loss; labels < 0 are ignored (one-hot formulation —
    shards cleanly when the vocab dim is model-parallel)."""
    from repro.parallel import ctx
    mask = (labels >= 0).astype(jnp.float32)
    lbl = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(lbl, logits.shape[-1], dtype=jnp.bfloat16)
    onehot = ctx.constrain(onehot, ctx.dp_axes(), None, "model")
    ll = jnp.sum(logits.astype(jnp.float32) * onehot, axis=-1)
    nll = (logz - ll) * mask
    zl = z_loss_w * jnp.square(logz) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll + zl) / denom, denom


def loss_fn(params, batch, cfg):
    logits, aux, x = forward(params, batch, cfg)
    loss, denom = cross_entropy(logits, batch["labels"])
    metrics = {"lm_loss": loss, "aux_loss": aux, "tokens": denom}
    if cfg.n_experts:
        loss = loss + 0.01 * aux
    if cfg.mtp:
        # DeepSeek-V3 multi-token prediction: one extra depth predicting t+2
        h = jnp.concatenate(
            [x[:, :-1], embed(params, batch["tokens"], cfg)[:, 1:]], axis=-1)
        h = pdot("bsd,de->bse", h, params["mtp_proj"], cfg.policy)
        B, S1 = h.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S1, dtype=jnp.int32)[None], (B, S1))
        h, _ = block_apply(params["mtp_block"], h, cfg, pos, 0,
                           moe=bool(cfg.n_experts))
        mtp_logits = unembed_logits(params, h, cfg)
        mtp_loss, _ = cross_entropy(mtp_logits, batch["labels"][:, 1:])
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ------------------------------------------------------------- serving

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    def one(_):
        if cfg.use_mla:
            return M.mla_init_cache(cfg, batch, max_len, dtype)
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                               dtype)}
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    caches = {}
    if n_dense:
        caches["dense_blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_dense,) + a.shape).copy(), one(0))
    if n_moe:
        caches["moe_blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_moe,) + a.shape).copy(), one(0))
    return caches


def prefill(params, cfg, tokens, positions=None):
    """Sequence-level prefill: ONE jitted forward through the fused sdpa
    route that returns both the logits and every layer's cache entries —
    replacing the legacy O(P) sequential ``decode_step`` prompt loop.

    tokens: (B, P) int32 (right-pad prompts; causal masking keeps padded
    tails from influencing earlier positions).  Returns ``(logits
    (B, P, V), kv)`` where ``kv`` mirrors the :func:`init_cache` tree with
    leaves (n_layers, B, P, ...) — callers place them into a dense cache
    (``launch/serve.py``) or scatter them into pages (``serving/engine``).
    """
    B, P = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None],
                                     (B, P))
    x = embed(params, tokens, cfg)
    windows = layer_windows(cfg, cfg.n_layers)
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    kv = {}
    if n_dense:
        x, _, kvs = stack_prefill(params["dense_blocks"], x, cfg, positions,
                                  windows[:n_dense], moe=False)
        kv["dense_blocks"] = kvs
    if n_moe:
        x, _, kvs = stack_prefill(params["moe_blocks"], x, cfg, positions,
                                  windows[n_dense:], moe=True)
        kv["moe_blocks"] = kvs
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return unembed_logits(params, x, cfg), kv


def prefill_chunk(params, cfg, cache, tokens, start):
    """One chunk of a chunked prefill: advance every layer's dense scratch
    cache by ``tokens`` (B, C) at absolute positions ``start .. start+C``
    and return the chunk's logits.

    ``cache`` is an :func:`init_cache` tree (leaves (nL, B, T, ...), f32
    for exact parity) holding every earlier chunk's K/V — and, on a
    prefix-cache hit, the gathered shared pages.  Returns ``(logits
    (B, C, V), new_cache)``.  Running all chunks then matches the
    monolithic :func:`prefill` row-for-row (the serving engine's
    chunked-prefill parity contract)."""
    B, C = tokens.shape
    x = embed(params, tokens, cfg)
    windows = layer_windows(cfg, cfg.n_layers)
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    new_cache = {}
    if n_dense:
        x, nc = stack_chunk(params["dense_blocks"], x, cfg,
                            cache["dense_blocks"], start,
                            windows[:n_dense], moe=False)
        new_cache["dense_blocks"] = nc
    if n_moe:
        x, nc = stack_chunk(params["moe_blocks"], x, cfg,
                            cache["moe_blocks"], start,
                            windows[n_dense:], moe=True)
        new_cache["moe_blocks"] = nc
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return unembed_logits(params, x, cfg), new_cache


def init_paged_cache(cfg, num_pages: int, page_size: int,
                     dtype=jnp.bfloat16):
    """Paged KV cache: the :func:`init_cache` tree with the dense (B, T)
    token axis replaced by a (num_pages, page_size) page pool shared
    across sequences (per-sequence block tables live in the serving
    engine).  Page 0 is the engine's scrap page — inactive slots write
    into it."""
    def one(_):
        if cfg.use_mla:
            return {"c_kv": jnp.zeros((num_pages, page_size,
                                       cfg.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((num_pages, page_size,
                                         cfg.qk_rope_dim), dtype)}
        return {"k": jnp.zeros((num_pages, page_size, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((num_pages, page_size, cfg.n_kv_heads,
                                cfg.head_dim), dtype)}
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    pools = {}
    if n_dense:
        pools["dense_blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_dense,) + a.shape).copy(), one(0))
    if n_moe:
        pools["moe_blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_moe,) + a.shape).copy(), one(0))
    return pools


def decode_step_paged(params, cfg, pools, block_tables, lengths, tokens):
    """One decode step against the paged cache with per-slot lengths.

    tokens: (B,) int32 — one token per sequence slot; block_tables: (B,
    maxp) i32; lengths: (B,) i32 tokens already cached per slot (the
    current token's position — slots at unequal depths decode together,
    which is what continuous batching is).  Returns (logits (B, V),
    new_pools)."""
    x = embed(params, tokens[:, None], cfg)
    windows = layer_windows(cfg, cfg.n_layers)
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    new_pools = {}
    if n_dense:
        x, npool = stack_decode_paged(params["dense_blocks"], x, cfg,
                                      pools["dense_blocks"], block_tables,
                                      lengths, windows[:n_dense], moe=False)
        new_pools["dense_blocks"] = npool
    if n_moe:
        x, npool = stack_decode_paged(params["moe_blocks"], x, cfg,
                                      pools["moe_blocks"], block_tables,
                                      lengths, windows[n_dense:], moe=True)
        new_pools["moe_blocks"] = npool
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_logits(params, x, cfg)
    return logits[:, 0], new_pools


def decode_step(params, cfg, cache, tokens, cache_index):
    """One decode step. tokens: (B,) int32; returns (logits, new_cache)."""
    B = tokens.shape[0]
    x = embed(params, tokens[:, None], cfg)
    windows = layer_windows(cfg, cfg.n_layers)
    nd = cfg.first_dense_layers
    n_moe = (cfg.n_layers - nd) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    new_cache = {}
    if n_dense:
        x, nc = stack_decode(params["dense_blocks"], x, cfg,
                             cache["dense_blocks"], cache_index,
                             windows[:n_dense], moe=False)
        new_cache["dense_blocks"] = nc
    if n_moe:
        x, nc = stack_decode(params["moe_blocks"], x, cfg,
                             cache["moe_blocks"], cache_index,
                             windows[n_dense:], moe=True)
        new_cache["moe_blocks"] = nc
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_logits(params, x, cfg)
    return logits[:, 0], new_cache


def forward_logits(params, batch, cfg):
    """Prefill entry: logits only (serving-side forward)."""
    return forward(params, batch, cfg)[0]
