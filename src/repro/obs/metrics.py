"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

One way to read system health.  Before this module, telemetry lived in
four ad-hoc surfaces — ``engine.stats()`` dicts, ``guard.counters()``,
``shmap.CALLS`` module globals, and the faults fire-log.  Those all still
work, but they now either write registry counters directly
(``kernels/shmap.py``) or are folded into :func:`snapshot` as read-time
*sources* (:func:`register_source`), so ``repro.obs.snapshot()`` is the
single answer to "what is this process doing".

Design constraints:

  * **stdlib only** — the registry is imported by the serving engine and
    the kernel dispatcher at module scope; it must never pull in JAX.
  * **thread-safe** — the engine's host loop, benchmark reps, and test
    threads all write concurrently; every mutation holds one module lock.
  * **labels** — a metric name plus a frozen ``k=v`` label set identifies
    one time series; snapshot keys render as ``name{k=v,...}``.
  * **values, not objects, reset** — :func:`reset` zeroes every series but
    keeps the metric objects and registered sources, so handles held by
    other modules stay valid across test-suite resets.
"""
from __future__ import annotations

import json
import threading

_LOCK = threading.RLock()
_METRICS: dict[str, "_Metric"] = {}
_SOURCES: dict[str, object] = {}

#: factor-2 ladder from 1 microsecond to ~17 minutes — the default for
#: wall-clock latency histograms (queue-wait / TTFT / TPOT).
TIME_BUCKETS_S = tuple(1e-6 * 2 ** i for i in range(31))

#: linear [0, 1] edges for fraction-valued observations (underflow fracs).
FRACTION_BUCKETS = tuple(i / 20 for i in range(21))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _series_name(name: str, key: tuple) -> str:
    if not key:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in key) + "}"


class _Metric:
    kind = "?"

    def __init__(self, name: str):
        self.name = name


class Counter(_Metric):
    """Monotonically increasing per-label-set totals."""
    kind = "counter"

    def __init__(self, name: str):
        super().__init__(name)
        self._values: dict[tuple, float] = {}

    def inc(self, n: float = 1, **labels):
        with _LOCK:
            key = _label_key(labels)
            self._values[key] = self._values.get(key, 0) + n

    def value(self, **labels) -> float:
        with _LOCK:
            return self._values.get(_label_key(labels), 0)

    def total(self) -> float:
        """Sum over every label set."""
        with _LOCK:
            return sum(self._values.values())

    def items(self) -> dict[str, float]:
        with _LOCK:
            return {_series_name(self.name, k): v
                    for k, v in self._values.items()}

    def reset(self):
        with _LOCK:
            self._values.clear()


class Gauge(_Metric):
    """Last-written value per label set, with running-extremum helpers."""
    kind = "gauge"

    def __init__(self, name: str):
        super().__init__(name)
        self._values: dict[tuple, float] = {}

    def set(self, v: float, **labels):
        with _LOCK:
            self._values[_label_key(labels)] = v

    def set_min(self, v: float, **labels):
        with _LOCK:
            key = _label_key(labels)
            cur = self._values.get(key)
            self._values[key] = v if cur is None else min(cur, v)

    def set_max(self, v: float, **labels):
        with _LOCK:
            key = _label_key(labels)
            cur = self._values.get(key)
            self._values[key] = v if cur is None else max(cur, v)

    def value(self, **labels):
        with _LOCK:
            return self._values.get(_label_key(labels))

    def items(self) -> dict[str, float]:
        with _LOCK:
            return {_series_name(self.name, k): v
                    for k, v in self._values.items()}

    def reset(self):
        with _LOCK:
            self._values.clear()


class Histogram(_Metric):
    """Fixed-bucket histogram: counts per ``(lo, hi]`` bucket plus an
    overflow slot, with sum/count and interpolated percentiles."""
    kind = "histogram"

    def __init__(self, name: str, buckets=TIME_BUCKETS_S):
        super().__init__(name)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {name!r} needs at least one bucket")
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}

    def observe(self, v: float, **labels):
        v = float(v)
        with _LOCK:
            key = _label_key(labels)
            counts = self._counts.setdefault(
                key, [0] * (len(self.buckets) + 1))
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            counts[i] += 1
            self._sums[key] = self._sums.get(key, 0.0) + v

    def _agg(self, labels: dict) -> tuple[list[int], float]:
        """Counts/sum for one label set, or merged over all sets when no
        labels are given."""
        with _LOCK:
            if labels:
                key = _label_key(labels)
                return (list(self._counts.get(
                    key, [0] * (len(self.buckets) + 1))),
                    self._sums.get(key, 0.0))
            merged = [0] * (len(self.buckets) + 1)
            for counts in self._counts.values():
                for i, c in enumerate(counts):
                    merged[i] += c
            return merged, sum(self._sums.values())

    def count(self, **labels) -> int:
        counts, _ = self._agg(labels)
        return sum(counts)

    def sum(self, **labels) -> float:
        _, s = self._agg(labels)
        return s

    def percentile(self, p: float, **labels) -> float:
        """Linear-interpolated percentile estimate from the bucket counts
        (0 when the histogram is empty)."""
        counts, _ = self._agg(labels)
        n = sum(counts)
        if n == 0:
            return 0.0
        target = (p / 100.0) * n
        cum = 0
        for i, c in enumerate(counts):
            if cum + c >= target and c > 0:
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = (self.buckets[i] if i < len(self.buckets)
                      else self.buckets[-1])
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.buckets[-1]

    def items(self) -> dict[str, dict]:
        with _LOCK:
            return {_series_name(self.name, k): {
                "buckets": list(self.buckets),
                "counts": list(c),
                "count": sum(c),
                "sum": self._sums.get(k, 0.0),
            } for k, c in self._counts.items()}

    def reset(self):
        with _LOCK:
            self._counts.clear()
            self._sums.clear()


# ------------------------------------------------------------- registry

def _get(name: str, cls, *args) -> _Metric:
    with _LOCK:
        m = _METRICS.get(name)
        if m is None:
            m = _METRICS[name] = cls(name, *args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                            f"{cls.kind}")
        return m


def counter(name: str, **labels) -> Counter:
    """Get-or-create; with labels, increments are ``counter(n, **labels)``
    on the returned object — this helper just resolves the metric."""
    return _get(name, Counter)


def gauge(name: str) -> Gauge:
    return _get(name, Gauge)


def histogram(name: str, buckets=None) -> Histogram:
    if buckets is None:
        return _get(name, Histogram)
    return _get(name, Histogram, buckets)


def inc(name: str, n: float = 1, **labels):
    counter(name).inc(n, **labels)


def observe(name: str, v: float, buckets=None, **labels):
    histogram(name, buckets).observe(v, **labels)


def set_gauge(name: str, v: float, **labels):
    gauge(name).set(v, **labels)


# -------------------------------------------------------------- sources
#
# A source is a zero-arg callable returning a flat {str: number} dict —
# the adapter mechanism folding pre-existing counter surfaces
# (guard.counters(), the faults fire-log, engine stats) into snapshot()
# without rewriting their owners.

def register_source(name: str, fn):
    with _LOCK:
        _SOURCES[name] = fn


def unregister_source(name: str):
    with _LOCK:
        _SOURCES.pop(name, None)


def read_sources() -> dict[str, dict]:
    with _LOCK:
        sources = dict(_SOURCES)
    return {name: dict(fn()) for name, fn in sources.items()}


# ------------------------------------------------- snapshot / diff / io

def snapshot(include_sources: bool = True) -> dict:
    """One nested dict of everything: ``{"counters": {series: total},
    "gauges": {...}, "histograms": {series: {buckets, counts, count,
    sum}}, "sources": {name: {...}}}``."""
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    with _LOCK:
        metrics = list(_METRICS.values())
    for m in metrics:
        if isinstance(m, Counter):
            out["counters"].update(m.items())
        elif isinstance(m, Gauge):
            out["gauges"].update(m.items())
        else:
            out["histograms"].update(m.items())
    if include_sources:
        out["sources"] = read_sources()
    return out


def diff(new: dict, old: dict) -> dict:
    """Delta between two snapshots: counter/source deltas (omitting
    zeros), changed gauges, and per-histogram count/sum deltas."""
    out = {"counters": {}, "gauges": {}, "histograms": {}, "sources": {}}
    for k, v in new.get("counters", {}).items():
        d = v - old.get("counters", {}).get(k, 0)
        if d:
            out["counters"][k] = d
    for k, v in new.get("gauges", {}).items():
        if old.get("gauges", {}).get(k) != v:
            out["gauges"][k] = v
    for k, v in new.get("histograms", {}).items():
        o = old.get("histograms", {}).get(k, {})
        dc = v["count"] - o.get("count", 0)
        if dc:
            out["histograms"][k] = {"count": dc,
                                    "sum": v["sum"] - o.get("sum", 0.0)}
    for src, vals in new.get("sources", {}).items():
        ovals = old.get("sources", {}).get(src, {})
        delta = {}
        for k, v in vals.items():
            if isinstance(v, (int, float)):
                d = v - ovals.get(k, 0)
                if d:
                    delta[k] = d
        if delta:
            out["sources"][src] = delta
    return out


def dump(path: str) -> str:
    """Write :func:`snapshot` as JSON; returns ``path``."""
    with open(path, "w") as f:
        json.dump(snapshot(), f, indent=2, sort_keys=True, default=str)
    return path


def reset():
    """Zero every metric series (objects and sources stay registered)."""
    with _LOCK:
        metrics = list(_METRICS.values())
    for m in metrics:
        m.reset()
