"""Dispatch explainability: *why* did a contraction (not) take the kernel?

Every trace-time routing decision in ``kernels/dispatch.py`` — for the
GEMM, flash-attention, and paged decode-attention kernels plus the
epilogue hook — records which rule accepted or declined it, keyed like
the circuit breaker: ``(backend, kernel, policy, shape-bucket)``.  The
rule slugs below name the numbered dispatch rules of docs/kernels.md and
the decision tree in docs/architecture.md (tests pin the mapping), so
``repro.obs.explain()`` replaces "add prints to dispatch.py" as the way
to answer "why is this shape on the XLA fallback?".

Decisions are recorded at *trace* time: a jitted caller contributes one
decision per (function, shape, config-epoch) trace, not per execution.
Counts also land in the metrics registry (``kernels/dispatch/route`` and
``kernels/dispatch/decline`` counters), so snapshots carry the totals
even after :func:`reset`.
"""
from __future__ import annotations

import threading

from . import metrics

#: rule slug -> human explanation.  "fused" is the acceptance; everything
#: else names the eligibility rule that declined to the XLA fallback.
RULES = {
    "fused": "routed to the fused Pallas TCEC kernel",
    "plain-policy": "plain policy (fp32/bf16): a single XLA dot, nothing "
                    "to correct or fuse (rule 1)",
    "policy-ineligible": "not a bf16 split policy — the fp16 reproduction "
                         "policies model CUDA Tensor Cores, which the "
                         "bf16 MXU kernel cannot (rule 1)",
    "hatch-disabled": "an escape hatch is off: REPRO_DISABLE_PALLAS / "
                      "REPRO_DISABLE_FLASH_ATTN / REPRO_DISABLE_PAGED_ATTN "
                      "/ fuse_epilogue (rule 5)",
    "off-backend": "backend is not TPU and force is unset (rule 4)",
    "shape-unsupported": "contraction does not map onto the kernel's "
                         "canonical (B?, M, K) @ (B?, K, N) layout "
                         "(rule 2)",
    "below-min-dim": "a problem dim is under min_dim — 128-padding would "
                     "cost more than fusion wins (rule 3)",
    "mesh-declined": "GSPMD mesh installed but the shard_map knob is off "
                     "or kernels/shmap.py has no per-shard spec for these "
                     "shapes (rule 6)",
    "vmem-budget": "even the minimum kernel block would not fit the VMEM "
                   "budget (extreme-rep GQA)",
    "breaker-open": "the circuit breaker has this key quarantined after "
                    "repeated kernel failures (kernels/guard.py)",
    "kernel-failure": "the kernel raised and guarded dispatch fell back "
                      "(kernels/guard.py counts the failure)",
}

_LOCK = threading.Lock()
_DECISIONS: dict[tuple, dict] = {}

#: bound on distinct decision keys (shape-sweep benchmarks); overflow is
#: counted, never silent.
MAX_KEYS = 4096


def record(kernel: str, policy: str, bucket: tuple, rule: str):
    """Record one routing decision.  ``bucket`` is the shape-bucket part
    of the key (the guard ident without the policy)."""
    if rule not in RULES:
        raise ValueError(f"unknown dispatch rule {rule!r}; "
                         f"known: {sorted(RULES)}")
    import jax
    backend = jax.default_backend()
    fused = rule == "fused"
    key = (backend, kernel, str(policy)) + tuple(
        str(b) for b in tuple(bucket))
    with _LOCK:
        rules = _DECISIONS.get(key)
        if rules is None:
            if len(_DECISIONS) >= MAX_KEYS:
                metrics.inc("kernels/dispatch/explain_overflow")
            else:
                rules = _DECISIONS[key] = {}
        if rules is not None:
            # per-rule counts: a key may flip route over its lifetime
            # (breaker opens, config scopes) — keep every decision
            rules[rule] = rules.get(rule, 0) + 1
    metrics.counter("kernels/dispatch/route").inc(
        kernel=kernel, route="fused" if fused else "fallback")
    if not fused:
        metrics.counter("kernels/dispatch/decline").inc(
            kernel=kernel, rule=rule)


class Report:
    """Materialized view of every recorded decision."""

    def __init__(self, entries: list[dict]):
        self.entries = entries

    @property
    def n_fused(self) -> int:
        return sum(e["count"] for e in self.entries
                   if e["rule"] == "fused")

    @property
    def n_fallback(self) -> int:
        return sum(e["count"] for e in self.entries
                   if e["rule"] != "fused")

    def fallbacks(self) -> list[dict]:
        return [e for e in self.entries if e["rule"] != "fused"]

    def lines(self) -> list[str]:
        out = []
        for e in sorted(self.entries,
                        key=lambda e: (-e["count"], e["key"])):
            label = ("fused" if e["rule"] == "fused"
                     else f"fallback({e['rule']})")
            out.append(f"{e['key']}: {label} x{e['count']}")
        return out

    def __str__(self):
        if not self.entries:
            return "dispatch explain: no decisions recorded"
        head = (f"dispatch explain: {self.n_fused} fused / "
                f"{self.n_fallback} fallback decisions")
        return "\n".join([head] + ["  " + ln for ln in self.lines()])


def report(reset: bool = False) -> Report:
    """Everything recorded so far (optionally clearing the table)."""
    with _LOCK:
        entries = [{"key": "/".join(key), "backend": key[0],
                    "kernel": key[1], "policy": key[2],
                    "bucket": key[3:], "rule": rule, "count": count}
                   for key, rules in _DECISIONS.items()
                   for rule, count in rules.items()]
        if reset:
            _DECISIONS.clear()
    return Report(entries)


def decisions() -> dict[str, dict]:
    """Raw ``{key: {rule: count}}`` view (keys "/"-joined)."""
    with _LOCK:
        return {"/".join(k): dict(v) for k, v in _DECISIONS.items()}


def reset():
    with _LOCK:
        _DECISIONS.clear()
