"""Context-scoped span tracing in the Chrome trace event format.

``trace()`` installs a :class:`Tracer` for its dynamic extent (innermost
wins — the same precedence discipline as ``numerics.use`` and
``faults.use``); instrumented code asks :func:`current` for the active
tracer and does nothing when there isn't one, so tracing-off costs one
thread-local read per instrumentation point and zero device work.

Events follow the Chrome trace event format (the JSON Perfetto and
``chrome://tracing`` load directly):

  * ``span(name)`` — a ``ph:"X"`` complete event with microsecond
    ``ts``/``dur``.  The context manager yields a mutable args dict, so
    annotations computed *inside* the block (batch occupancy, clock)
    land on the exported event.
  * ``instant(name)`` — a ``ph:"i"`` thread-scoped instant.
  * ``async_begin/instant/end(name, id)`` — ``ph:"b"/"n"/"e"`` async
    events keyed by id: one per *request*, spanning its whole lifetime
    across engine steps (enqueue -> admission -> ... -> finish), however
    many spans interleave in between.

Export: :meth:`Tracer.export` writes ``{"traceEvents": [...]}`` JSON, or
one event per line when the path ends in ``.jsonl``.  The engine's
latency *distributions* (queue-wait, TTFT, TPOT) are not derived from
the events — instrumentation records them straight into
``obs.metrics`` histograms while the tracer is active.

The clock is injectable (``Tracer(clock=...)``) so tests drive spans
deterministically; the default is ``time.perf_counter``.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time

_TLS = threading.local()
_LAST_LOCK = threading.Lock()
_LAST = None


class Tracer:
    """An event buffer plus the clock it timestamps against."""

    def __init__(self, clock=None):
        self._clock = clock if clock is not None else time.perf_counter
        self._t0 = self._clock()
        self._lock = threading.Lock()
        self._tids: dict[int, int] = {}
        self.events: list[dict] = []

    def now(self) -> float:
        """Seconds on this tracer's clock — what instrumentation uses for
        latency arithmetic (monotonic; not wall time)."""
        return self._clock()

    def _ts(self) -> float:
        return (self._clock() - self._t0) * 1e6     # microseconds

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            return self._tids.setdefault(ident, len(self._tids))

    def _emit(self, ev: dict):
        with self._lock:
            self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "span", **args):
        """Complete-event span; yields the event's mutable args dict."""
        t0 = self._ts()
        a = dict(args)
        try:
            yield a
        finally:
            self._emit({"name": name, "cat": cat, "ph": "X", "ts": t0,
                        "dur": self._ts() - t0, "pid": 0,
                        "tid": self._tid(), "args": a})

    def instant(self, name: str, cat: str = "event", **args):
        self._emit({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self._ts(), "pid": 0, "tid": self._tid(),
                    "args": dict(args)})

    def async_begin(self, name: str, aid, cat: str = "request", **args):
        self._emit({"name": name, "cat": cat, "ph": "b", "id": aid,
                    "ts": self._ts(), "pid": 0, "tid": self._tid(),
                    "args": dict(args)})

    def async_instant(self, name: str, aid, cat: str = "request", **args):
        self._emit({"name": name, "cat": cat, "ph": "n", "id": aid,
                    "ts": self._ts(), "pid": 0, "tid": self._tid(),
                    "args": dict(args)})

    def async_end(self, name: str, aid, cat: str = "request", **args):
        self._emit({"name": name, "cat": cat, "ph": "e", "id": aid,
                    "ts": self._ts(), "pid": 0, "tid": self._tid(),
                    "args": dict(args)})

    def chrome(self) -> dict:
        """The buffer as a Chrome-trace/Perfetto JSON object."""
        with self._lock:
            return {"traceEvents": list(self.events),
                    "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the events to ``path``: Chrome-trace JSON, or JSONL (one
        event per line) when the path ends in ``.jsonl``."""
        path = str(path)
        if path.endswith(".jsonl"):
            with self._lock:
                events = list(self.events)
            with open(path, "w") as f:
                for ev in events:
                    f.write(json.dumps(ev, sort_keys=True, default=str))
                    f.write("\n")
        else:
            with open(path, "w") as f:
                json.dump(self.chrome(), f, sort_keys=True, default=str)
        return path


# ----------------------------------------------------------- the context

def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


@contextlib.contextmanager
def trace(tracer: Tracer | None = None, clock=None):
    """Install a tracer for the dynamic extent; yields it.  On exit the
    tracer becomes the process's *last* tracer so ``obs.export(path)``
    can write it out after the traced region ends."""
    global _LAST
    tr = tracer if tracer is not None else Tracer(clock=clock)
    st = _stack()
    st.append(tr)
    try:
        yield tr
    finally:
        st.pop()
        with _LAST_LOCK:
            _LAST = tr


def current() -> Tracer | None:
    """The innermost active tracer on this thread, or None — the gate
    every instrumentation point checks first."""
    st = getattr(_TLS, "stack", None)
    return st[-1] if st else None


def last() -> Tracer | None:
    """The active tracer if any, else the most recently exited one."""
    cur = current()
    if cur is not None:
        return cur
    with _LAST_LOCK:
        return _LAST
