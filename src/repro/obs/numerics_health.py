"""Runtime numerics-health monitors: the paper's underflow-risk
indicators, live per contraction instead of offline per figure.

Fig. 8/11 of the source paper show the correction scheme silently losing
accuracy when operand exponents drift low: the residual ``dA = A - A_hi``
(scaled by ``2^scale_bits``, Eq. 18) lands in the low-precision format's
(sub)normal band, and correction-term products ``dA·B`` / ``A·dB``
underflow the accumulation.  The repo could only measure this offline
(``core/theory.py`` closed forms, the fig8 bench); these probes estimate
the same indicators on *live traffic*:

  * fraction of residuals whose scaled low-precision cast fully
    underflows (``u``) or lands subnormal (``gu``) — the empirical
    counterpart of ``theory.p_underflow`` / ``p_underflow_gradual``;
  * fraction of (sampled) correction-term products ``|dA_scaled|·|B_hi|``
    below the format's smallest normal;
  * operand exponent range vs :func:`safe_exponent_range` — the band of
    unbiased f32 exponents for which the closed-form P_{u+gu} is exactly
    zero and the scaled residual cannot overflow.

NB on flush-to-zero backends (XLA CPU flushes f32 subnormals) a bf16
residual that would land subnormal reads as exactly zero *before* the
probe sees it — bf16 shares f32's exponent range, so its whole
(sub)normal-underflow band lies inside the flushed region and ``u`` /
``gu`` stay at 0 there.  The exponent-range indicator (``oob`` vs
:func:`safe_exponent_range`) is backend-independent and is the robust
signal for bf16 policies; the fp16 policies (min normal ``2^-14``)
show ``gu`` directly on any backend.

Default **off** (``NumericsConfig.monitor`` / ``REPRO_MONITOR``).  When
on, :func:`observe` is called from the contraction chokepoints in
``core/policy.py`` (``pdot`` / ``policy_mm`` / ``policy_bmm``, forward
operands — the backward GEMMs run inside ``custom_vjp`` and are not
probed).  The probes compute side values only — the contraction's own
graph is untouched, so outputs stay token-identical (test-pinned) — and
deliver results at *runtime* through ``jax.debug.callback`` into the
``numerics/monitor/*`` registry metrics.  With the knob off no probe
ops enter the graph, so lowering is byte-identical to pre-monitor.
"""
from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp

from repro.core import theory
from . import metrics

_FMT = dict(theory.FORMATS_BY_DTYPE)          # dtype name -> LPFormat
_MAX_E = {d: theory.MAX_UNBIASED_EXP[f.name]  # max unbiased exponent
          for d, f in _FMT.items()}

#: an observed (gradual-)underflow fraction above this raises the
#: ``numerics/monitor/*_risk`` counters
RISK_THRESHOLD = 0.01

#: per-operand sample size for the product probe (|dA|x|B| outer product
#: over strided subsamples: 64x64 = 4096 products per probed contraction)
PRODUCT_SAMPLE = 64

_SAMPLE_LOCK = threading.Lock()
_sample_every = 1
_calls = 0


def configure(sample_every: int = 1):
    """Probe every Nth monitored contraction (trace-time sampling; a
    cached jit trace keeps whatever the counter decided when it was
    traced)."""
    global _sample_every
    _sample_every = max(1, int(sample_every))


@functools.lru_cache(maxsize=None)
def safe_exponent_range(dtype: str, scale_bits: int) -> tuple[int, int]:
    """Unbiased f32 operand exponents for which the residual cast is
    exact: the closed form ``theory.p_underflow_gradual(e, fmt,
    scale_bits)`` is 0.0 at the low end, and the scaled residual cannot
    exceed the format's max exponent at the high end.  May be empty
    (lo > hi) for fp8_e4m3 — see ``theory.safe_exponent_range``."""
    return theory.safe_exponent_range(_FMT[dtype], scale_bits,
                                      _MAX_E[dtype])


def _subsample(flat, n: int):
    flat = flat.reshape(-1)
    stride = max(1, int(flat.shape[0]) // n)
    return flat[::stride][:n]


def _operand_probe(x, policy):
    """In-graph probe values for one operand: underflow fractions of the
    first (dominant) residual's scaled cast, exponent extrema, and the
    fraction of nonzero elements outside the policy's safe range.
    Returns ``(stats, scaled_resid_f32, hi_f32)``."""
    fmt = _FMT[policy.dtype]
    lo_e, hi_e = safe_exponent_range(policy.dtype, policy.scale_bits)
    xf = x.astype(jnp.float32)
    hi = xf.astype(policy.jdtype).astype(jnp.float32)
    resid = xf - hi                                  # true correction term
    scaled = ((resid * jnp.float32(2.0 ** policy.scale_bits))
              .astype(policy.jdtype).astype(jnp.float32))
    nz = resid != 0
    n = jnp.maximum(jnp.sum(nz), 1)
    tiny = jnp.float32(2.0 ** -(fmt.bias - 1))       # smallest lp normal
    u = jnp.sum((scaled == 0) & nz) / n
    gu = jnp.sum((jnp.abs(scaled) < tiny) & nz) / n  # includes full u
    ax = jnp.abs(xf)
    nzx = ax > 0
    one = jnp.float32(1.0)
    ex = jnp.floor(jnp.log2(jnp.where(nzx, ax, one)))
    nx = jnp.maximum(jnp.sum(nzx), 1)
    oob = jnp.sum(((ex < lo_e) | (ex > hi_e)) & nzx) / nx
    zero = jnp.float32(0.0)
    stats = {"u": u, "gu": gu, "oob": oob,
             "emin": jnp.min(jnp.where(nzx, ex, zero)),
             "emax": jnp.max(jnp.where(nzx, ex, zero))}
    return stats, scaled, hi


def _product_underflow(scaled_resid, other_hi, tiny):
    """Fraction of sampled correction-term products below the format's
    smallest normal — the term that silently vanishes from the corrected
    accumulation (paper fig. 8)."""
    sa = _subsample(jnp.abs(scaled_resid), PRODUCT_SAMPLE)
    sb = _subsample(jnp.abs(other_hi), PRODUCT_SAMPLE)
    prod = sa[:, None] * sb[None, :]
    nz = prod != 0
    n = jnp.maximum(jnp.sum(nz), 1)
    return jnp.sum((prod < tiny) & nz) / n


def _record(u, gu, oob, pf, emin, emax, *, site, policy):
    """Host-side sink (runs per execution via jax.debug.callback)."""
    m = metrics
    m.counter("numerics/monitor/probes").inc(site=site, policy=policy)
    m.observe("numerics/monitor/underflow_frac", float(gu),
              buckets=m.FRACTION_BUCKETS, policy=policy)
    m.observe("numerics/monitor/product_underflow_frac", float(pf),
              buckets=m.FRACTION_BUCKETS, policy=policy)
    m.observe("numerics/monitor/exponent_oob_frac", float(oob),
              buckets=m.FRACTION_BUCKETS, policy=policy)
    m.gauge("numerics/monitor/exponent_min").set_min(float(emin),
                                                     policy=policy)
    m.gauge("numerics/monitor/exponent_max").set_max(float(emax),
                                                     policy=policy)
    if float(gu) > RISK_THRESHOLD or float(oob) > 0.0:
        m.counter("numerics/monitor/underflow_risk").inc(site=site,
                                                         policy=policy)
    if float(pf) > RISK_THRESHOLD:
        m.counter("numerics/monitor/product_underflow_risk").inc(
            site=site, policy=policy)


def observe(a, b, policy, *, site: str = "pdot"):
    """Probe one contraction's operands (split policies only).  Pure
    observation: emits side computations plus one debug callback; the
    contraction itself is untouched."""
    global _calls
    with _SAMPLE_LOCK:
        _calls += 1
        if (_calls - 1) % _sample_every:
            return
    fmt = _FMT[policy.dtype]
    tiny = jnp.float32(2.0 ** -(fmt.bias - 1))
    sa, ra, ha = _operand_probe(a, policy)
    sb, rb, hb = _operand_probe(b, policy)
    pf = jnp.maximum(_product_underflow(ra, hb, tiny),
                     _product_underflow(rb, ha, tiny))
    jax.debug.callback(
        functools.partial(_record, site=site, policy=policy.name),
        jnp.maximum(sa["u"], sb["u"]), jnp.maximum(sa["gu"], sb["gu"]),
        jnp.maximum(sa["oob"], sb["oob"]), pf,
        jnp.minimum(sa["emin"], sb["emin"]),
        jnp.maximum(sa["emax"], sb["emax"]))
