"""repro.obs — unified telemetry: one registry, four layers.

  * **metrics** (:mod:`repro.obs.metrics`) — process-wide counters,
    gauges, and fixed-bucket histograms with labels, snapshot/reset/diff,
    and read-time *sources* folding the pre-existing counter surfaces in
    (circuit breaker, faults fire-log, live engines' stats).
  * **tracing** (:mod:`repro.obs.trace`) — context-scoped
    ``obs.trace()`` spans through the serving request lifecycle,
    exportable as Chrome-trace/Perfetto JSON or JSONL via
    :func:`export`; while active, the engine records queue-wait / TTFT /
    TPOT into ``serving/latency/*`` histograms.
  * **dispatch explainability** (:mod:`repro.obs.explain`) — every
    trace-time kernel-routing decision records which rule declined (or
    accepted); :func:`explain` reports them.
  * **numerics health** (:mod:`repro.obs.numerics_health`) — sampled
    underflow-risk probes per contraction, off by default
    (``NumericsConfig.monitor`` / ``REPRO_MONITOR``).

See docs/observability.md for a guided tour.  This package stays
JAX-free at import time (the engine and dispatcher import it at module
scope).
"""
from __future__ import annotations

import contextlib

from . import metrics
from .explain import report as _explain_report
from .explain import reset as _explain_reset
from .trace import Tracer, current as current_tracer, last as last_tracer
from .trace import trace  # the context manager (shadows the submodule name;
#                           import the module as ``repro.obs.trace`` —
#                           ``from repro.obs.trace import ...`` still works)

__all__ = ["metrics", "trace", "Tracer", "current_tracer", "last_tracer",
           "export", "snapshot", "diff", "reset", "explain",
           "add_cli_flags", "cli_session"]


def snapshot(include_sources: bool = True) -> dict:
    """Everything the registry knows, plus the folded sources."""
    return metrics.snapshot(include_sources=include_sources)


def diff(new: dict, old: dict) -> dict:
    return metrics.diff(new, old)


def reset():
    """Zero every metric series and forget recorded dispatch decisions."""
    metrics.reset()
    _explain_reset()


def explain(reset: bool = False):
    """The dispatch-explainability report: every recorded routing
    decision with the rule that made it (see :mod:`repro.obs.explain`)."""
    return _explain_report(reset=reset)


def export(path: str, tracer: Tracer | None = None) -> str:
    """Write the active (or most recently exited) tracer's events to
    ``path`` — Chrome-trace JSON, or JSONL for ``.jsonl`` paths."""
    tr = tracer if tracer is not None else last_tracer()
    if tr is None:
        raise RuntimeError(
            "no tracer to export: run inside repro.obs.trace() first")
    return tr.export(path)


# ------------------------------------------------------ default sources
#
# The pre-obs counter surfaces, folded into snapshot() at read time.
# Imports stay inside the closures: registering costs nothing and pulls
# in no subsystem until someone actually snapshots.

def _guard_source() -> dict:
    from repro.kernels import guard
    return dict(guard.counters())


def _faults_source() -> dict:
    from repro import faults
    plan = faults.active()
    out: dict[str, int] = {}
    if plan is not None:
        for site, _idx in plan.log:
            out[site] = out.get(site, 0) + 1
    return out


metrics.register_source("kernels/guard", _guard_source)
metrics.register_source("faults/fired", _faults_source)


# ----------------------------------------------------------- CLI surface

def add_cli_flags(parser):
    """``--trace`` / ``--metrics-out`` for the launch CLIs."""
    parser.add_argument(
        "--trace", default="", metavar="PATH",
        help="run under repro.obs.trace() and export the request/step "
             "spans to PATH as Chrome-trace/Perfetto JSON (.jsonl for "
             "one event per line)")
    parser.add_argument(
        "--metrics-out", default="", metavar="PATH",
        help="write a repro.obs metrics snapshot (counters, latency "
             "histograms, folded sources) to PATH as JSON after the run")


@contextlib.contextmanager
def cli_session(args):
    """Shared ``--trace``/``--metrics-out`` driver: run the body under a
    tracer when requested; afterwards export the trace, dump the metrics
    snapshot, and print the dispatch-explain summary."""
    tracing = bool(getattr(args, "trace", ""))
    metrics_out = getattr(args, "metrics_out", "")
    scope = trace() if tracing else contextlib.nullcontext()
    with scope:
        yield
    if not tracing and not metrics_out:
        return
    if tracing:
        tr = last_tracer()
        tr.export(args.trace)
        print(f"telemetry: trace -> {args.trace} "
              f"({len(tr.events)} events)", flush=True)
    if metrics_out:
        metrics.dump(metrics_out)
        print(f"telemetry: metrics -> {metrics_out}", flush=True)
    rep = explain()
    print(f"dispatch explain: {rep.n_fused} fused / "
          f"{rep.n_fallback} fallback decisions", flush=True)
    for line in rep.lines()[:12]:
        print(f"  {line}", flush=True)
