"""Paged KV-cache pool: fixed-size pages, per-sequence block tables.

The legacy serving path allocated a dense ``(B, max_len)`` cache per batch
— every request paid for the longest possible sequence, and requests of
different lengths could not share a batch.  Here the cache is a pool of
fixed-size pages shared by every in-flight request: a request holds
``ceil(len / page_size)`` pages, listed in its block-table row, and frees
them the moment it completes.  Fragmentation is bounded to one partial
page per sequence (the vLLM PagedAttention memory model).

Split of responsibilities:

  * :class:`PagePool` — the host-side allocator: free-list bookkeeping
    only, no device arrays.  Page 0 is reserved as the **scrap page**:
    inactive engine slots point their block tables at it, so their masked
    decode writes land somewhere harmless.
  * the device-side page arrays live in the model tree
    (``models.lm.init_paged_cache``) and are updated functionally inside
    the jitted decode step; :func:`write_prompt_pages` scatters a
    sequence-level prefill's K/V into freshly allocated pages, and
    :func:`permute_pages` applies a defrag permutation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .errors import PagePoolError

DEFAULT_PAGE_SIZE = 16

# The pool arrays are the dominant serving allocation and every update
# rebinds them, so donate the input buffers for in-place updates — except
# on CPU, where XLA doesn't implement donation and would warn per compile.
_DONATE = () if jax.default_backend() == "cpu" else (0,)


class PagePool:
    """Host-side page allocator over ``num_pages`` fixed-size pages.

    LIFO free list: recently freed pages are reused first, which keeps the
    hot working set small.  ``alloc`` is all-or-nothing — a partial grant
    would deadlock two growing requests against each other.

    Pages are **refcounted** so the prefix cache can share them across
    requests (and hold its own reference): ``alloc`` hands out pages at
    refcount 1, :meth:`share` adds owners, and :meth:`free` only returns a
    page to the free list when its last owner lets go.  Uniquely-owned
    pages behave exactly as before — the refcounts are invisible to
    callers that never share.
    """

    def __init__(self, num_pages: int, page_size: int = DEFAULT_PAGE_SIZE):
        # real checks, not asserts: these guard user-supplied sizing and
        # must survive python -O
        if num_pages < 2:
            raise ValueError("need at least the scrap page + one real page; "
                             f"got num_pages={num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        # page 0 is the scrap page — never handed out
        self._free = list(range(num_pages - 1, 0, -1))
        self._ref: dict[int, int] = {}          # live page -> owner count

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (+ nothing: callers add their
        own growth headroom)."""
        return max(1, -(-n_tokens // self.page_size))

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages, or None (and no change) if they don't fit.

        The ``pool.alloc`` fault site injects transient exhaustion here
        (returns None with pages available) — the same signal callers
        must already handle, so every alloc site is chaos-testable.
        """
        from repro import faults
        if faults.poke("pool.alloc") is not None:
            return None
        if n > len(self._free):
            return None
        taken = self._free[-n:][::-1]
        del self._free[-n:]
        for p in taken:
            self._ref[p] = 1
        return taken

    def share(self, pages: list[int]) -> None:
        """Add one owner to each page (prefix-cache sharing).  Only live
        pages can gain owners — sharing a free page is a bookkeeping bug
        of the same severity as a double free."""
        for p in pages:
            if self._ref.get(p, 0) < 1:
                raise PagePoolError(f"share of non-live page {p}")
        for p in pages:
            self._ref[p] += 1

    def refcount(self, p: int) -> int:
        """Current owner count of page ``p`` (0 = free)."""
        return self._ref.get(p, 0)

    def free(self, pages: list[int]) -> None:
        """Drop one owner per page; pages reaching zero owners return to
        the free list.  Freeing a page that has no owners is still a
        double free."""
        for p in pages:
            if not 0 < p < self.num_pages:
                raise PagePoolError(f"free of out-of-range page {p} "
                                    f"(pool has {self.num_pages})")
            if self._ref.get(p, 0) < 1:
                raise PagePoolError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)

    def defrag(self) -> dict[int, int]:
        """Compact live pages onto the lowest indices.

        Returns the ``{old: new}`` mapping for live pages (identity
        entries included) and rebuilds the free list above them.  Callers
        must re-index their block tables and apply the same permutation
        to the device page arrays (:func:`permute_pages`) — the pool only
        does the bookkeeping.
        """
        live = sorted(set(range(1, self.num_pages)) - set(self._free))
        mapping = {old: new for new, old in enumerate(live, start=1)}
        self._free = list(range(self.num_pages - 1, len(live), -1))
        # refcounts travel with their pages: a shared page moves ONCE and
        # every owner's mapping update finds the same count at the new slot
        self._ref = {mapping[p]: c for p, c in self._ref.items()}
        return mapping


# ------------------------------------------------------- device helpers

@functools.partial(jax.jit, donate_argnums=_DONATE)
def write_prompt_pages(pools, kv, pages):
    """Scatter a sequence-level prefill's K/V into allocated pages.

    pools: the ``init_paged_cache`` tree, leaves (nL, NP, ps, ...);
    kv: the matching ``prefill`` tree, leaves (nL, B, P, ...) with ``P``
    a multiple of ``ps`` (right-pad prompts to the page size — padded
    positions are masked by the sequence length and overwritten as decode
    proceeds); pages: (B, P // ps) i32 page indices per sequence.
    """
    flat = pages.reshape(-1)

    def one(pool, k):
        nL, B, P = k.shape[:3]
        ps = pool.shape[2]
        kp = k.reshape((nL, B * (P // ps), ps) + k.shape[3:])
        return pool.at[:, flat].set(kp.astype(pool.dtype))

    return jax.tree.map(one, pools, kv)


@jax.jit
def load_pages_into_scratch(scratch, pools, pages):
    """Gather cached prefix pages into the head of a per-request dense
    scratch cache (chunked prefill over a prefix-cache hit).

    scratch: an ``init_cache(batch=1, ...)`` tree, leaves (nL, 1, T, ...);
    pools: the page-pool tree, leaves (nL, NP, ps, ...); pages: (n,) i32
    with ``n * ps <= T``.  The gathered tokens land at positions
    ``[0, n * ps)`` — the prefix the tail chunks attend over.
    """
    def one(s, pool):
        g = pool[:, pages]                            # (nL, n, ps, ...)
        g = g.reshape((g.shape[0], 1, g.shape[1] * g.shape[2]) + g.shape[3:])
        return jax.lax.dynamic_update_slice(s, g.astype(s.dtype),
                                            (0,) * s.ndim)

    return jax.tree.map(one, scratch, pools)


@functools.partial(jax.jit, donate_argnums=_DONATE)
def write_span_pages(pools, scratch, start, pages):
    """Scatter one chunk's token span from the scratch cache into pages.

    pools: leaves (nL, NP, ps, ...); scratch: leaves (nL, 1, T, ...);
    start: i32 token index of the span (page-aligned); pages: (n,) i32 —
    the span covers tokens ``[start, start + n * ps)``.  The f32 scratch
    values cast to the pool dtype exactly as a monolithic prefill's
    ``write_prompt_pages`` would, so chunked and single-shot prefill land
    bitwise-identical pages.
    """
    def one(pool, s):
        nL = s.shape[0]
        ps = pool.shape[2]
        n = pages.shape[0]
        span = jax.lax.dynamic_slice_in_dim(s[:, 0], start, n * ps, axis=1)
        sp = span.reshape((nL, n, ps) + span.shape[2:])
        return pool.at[:, pages].set(sp.astype(pool.dtype))

    return jax.tree.map(one, pools, scratch)


@functools.partial(jax.jit, donate_argnums=_DONATE)
def permute_pages(pools, perm):
    """Apply a defrag permutation to the device page arrays.

    perm: (NP,) i32 with ``perm[new] = old`` (identity off the live set) —
    i.e. the inverse of :meth:`PagePool.defrag`'s ``{old: new}`` mapping.
    """
    return jax.tree.map(lambda pool: pool[:, perm], pools)


def inverse_permutation(mapping: dict[int, int], num_pages: int):
    """Turn defrag's ``{old: new}`` into the (NP,) gather index
    ``perm[new] = old`` that :func:`permute_pages` wants."""
    perm = list(range(num_pages))
    for old, new in mapping.items():
        perm[new] = old
    return jnp.asarray(perm, jnp.int32)
