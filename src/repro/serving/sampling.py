"""Per-request sampling: temperature / top-k / top-p / greedy + stop tokens.

The legacy serve loop had exactly two modes — batch-wide greedy or
batch-wide ``jax.random.categorical`` — and always generated ``gen_len``
tokens, sailing straight past any end-of-sequence token.  Here every
request carries its own :class:`SamplingParams`, and :func:`sample` draws
one token per engine slot under that slot's parameters in a single jitted
call (the per-slot knobs are traced vectors, so a mixed greedy/sampled
batch costs one dispatch).

Filtering order follows the standard serving convention: temperature
scales the logits, top-k masks to the k highest, top-p (nucleus) keeps the
smallest set whose probability mass reaches p — top-p is applied to the
top-k-filtered distribution.  Rows with ``temperature <= 0`` are greedy
argmax regardless of the other knobs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38   # matches models.layers.NEG_INF (finite: no NaN algebra)


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decode parameters.

    temperature: 0 (or below) means greedy argmax.
    top_k: keep only the k highest-logit tokens (0 = off).
    top_p: nucleus sampling — keep the smallest set of tokens whose
        cumulative probability reaches ``top_p`` (1.0 = off).
    max_tokens: hard cap on generated tokens.
    stop_tokens: generation ends when one is sampled; the stop token is
        not included in the output.
    seed: per-request PRNG seed — a request's key stream advances once
        per generated token regardless of batch composition, so
        continuous batching never changes sampled output.  (Preemption
        keeps the stream aligned too, but its re-prefill recomputes the
        next-token logits through the sequence path, which can differ
        from the decode path at ULP level.)
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    max_tokens: int = 16
    stop_tokens: tuple[int, ...] = field(default_factory=tuple)
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def _top_k_mask(logits, k):
    """Mask logits outside each row's k highest.  k: (B,) i32, 0 = off."""
    V = logits.shape[-1]
    kk = jnp.clip(k, 1, V)
    sorted_desc = -jnp.sort(-logits, axis=-1)
    kth = jnp.take_along_axis(sorted_desc, (kk - 1)[:, None], axis=-1)
    keep = (k <= 0)[:, None] | (logits >= kth)
    return jnp.where(keep, logits, NEG_INF)


def _top_p_mask(logits, p):
    """Nucleus mask: keep the smallest prefix of the sorted distribution
    whose cumulative probability reaches p.  p: (B,) f32, >= 1 = off."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    sp = -jnp.sort(-probs, axis=-1)
    csum = jnp.cumsum(sp, axis=-1)
    # first sorted index where the cumulative mass reaches p; every token
    # with probability >= that threshold is kept (ties keep extra mass)
    idx = jnp.argmax(csum >= p[:, None], axis=-1)
    thr = jnp.take_along_axis(sp, idx[:, None], axis=-1)
    keep = (p >= 1.0)[:, None] | (probs >= thr)
    return jnp.where(keep, logits, NEG_INF)


def sample(logits, temperature, top_k, top_p, keys):
    """Draw one token per row under per-row parameters.

    logits: (B, V) f32; temperature/top_p: (B,) f32; top_k: (B,) i32;
    keys: (B, 2) uint32 — one PRNG key per row, so every request's stream
    is deterministic regardless of batch composition.  Returns (B,) i32.
    """
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    masked = _top_p_mask(_top_k_mask(scaled, top_k), top_p)
    drawn = jax.vmap(lambda key, row: jax.random.categorical(key, row))(
        keys, masked).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy_tok, drawn)


def sample_one(logits, params: SamplingParams, key):
    """Single-row convenience over :func:`sample` (prefill-time draw)."""
    return sample(logits[None],
                  jnp.asarray([params.temperature], jnp.float32),
                  jnp.asarray([params.top_k], jnp.int32),
                  jnp.asarray([params.top_p], jnp.float32),
                  key[None])[0]
