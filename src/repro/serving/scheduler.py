"""Continuous-batching scheduler: FIFO admission, page budget, preemption.

The scheduler owns the *policy* half of the serving engine: which waiting
request is admitted into which slot, when a running request may grow by a
page, and who gets evicted when the page pool runs dry.  The engine owns
the *mechanism* (device arrays, jitted steps) and calls in here.

Decisions (deliberately boring, and unit-tested as such):

  * **admission** is strict FIFO — if the head of the queue doesn't fit
    (no free slot, or not enough pages for its prompt plus one growth
    page), nothing behind it is admitted either.  No head-of-line bypass:
    starvation-freedom is worth more than packing efficiency here.
  * **preemption** evicts the *most recently admitted* running request
    (LIFO victim, the vLLM recency rule): it has the least sunk compute,
    and the scheme is deadlock-free because the oldest request can always
    run alone.  The victim's pages are freed and it is pushed back to the
    *front* of the waiting queue with its generated tokens intact — on
    re-admission its prompt is ``prompt + generated`` (recompute-style
    preemption; no page swapping).
  * **preemption-storm parking**: a request evicted ``max_preemptions``
    times is *parked* instead of requeued — it sits out until the waiting
    queue drains, then rejoins at the front.  Recompute-style preemption
    re-prefills the victim's whole sequence, so a thrashing mix (pool
    slightly too small for the resident set) can burn most of its steps
    re-prefilling the same requests; parking converts that storm into
    ordinary queueing delay.  FIFO fairness survives because parking
    only triggers *after* repeated evictions, and a parked request
    re-enters at the head.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from enum import Enum

from .errors import SchedulerInvariantError
from .kv_cache import PagePool
from .sampling import SamplingParams


class RequestState(Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"      # admitted, prompt prefilling in chunks
    RUNNING = "running"
    FINISHED = "finished"


@dataclass
class Request:
    """One serving request plus its runtime bookkeeping."""
    rid: int
    prompt: list[int]
    params: SamplingParams
    state: RequestState = RequestState.WAITING
    out: list[int] = field(default_factory=list)
    slot: int | None = None
    pages: list[int] = field(default_factory=list)
    n_preemptions: int = 0
    key: object = None          # per-request PRNG key (engine-owned)
    finish_reason: str | None = None   # serving.errors.FinishReason value
    deadline: int | None = None        # engine-clock tick to finish by
    n_prefill_faults: int = 0          # failed prefill attempts (engine)
    t_enqueue: float | None = None     # tracer clock at add (repro.obs)
    t_last_token: float | None = None  # tracer clock at last accept
    prefill_done: int = 0              # tokens prefilled so far (chunked)
    scratch: object = None             # per-request dense scratch cache
    shared_pages: int = 0              # head pages mapped from the cache

    @property
    def full_sequence(self) -> list[int]:
        """Prompt plus everything generated so far — what a re-admission
        after preemption must prefill."""
        return list(self.prompt) + list(self.out)

    @property
    def finished(self) -> bool:
        return self.state is RequestState.FINISHED


class Scheduler:
    """FIFO admission + LIFO preemption over a :class:`PagePool`."""

    def __init__(self, pool: PagePool, max_slots: int,
                 max_preemptions: int | None = None):
        self.pool = pool
        self.max_slots = max_slots
        self.max_preemptions = max_preemptions         # None = never park
        self.waiting: deque[Request] = deque()
        self.parked: deque[Request] = deque()          # storm victims
        self.running: dict[int, Request] = {}          # slot -> request
        self._ids = itertools.count()
        self._admit_seq = itertools.count()            # recency for victims
        self._admitted_at: dict[int, int] = {}         # rid -> seq
        self.n_preemptions = 0                         # total evictions
        self.n_parks = 0                               # storm detections
        # engine-installed prefix-cache eviction hook: called with a page
        # shortfall when the pool is dry, returns pages actually freed.
        # Tried once per failed allocation, before FIFO-blocking an
        # admission or preempting a running request.
        self.evict_cb = None

    # ------------------------------------------------------------ intake

    def add(self, prompt, params: SamplingParams | None = None,
            rid: int | None = None) -> Request:
        req = Request(rid=next(self._ids) if rid is None else rid,
                      prompt=[int(t) for t in prompt],
                      params=params or SamplingParams())
        self.waiting.append(req)
        return req

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.parked or self.running)

    def free_slots(self) -> list[int]:
        return [s for s in range(self.max_slots) if s not in self.running]

    # --------------------------------------------------------- admission

    def _alloc(self, n: int) -> list[int] | None:
        """``pool.alloc`` with one prefix-cache eviction retry: when the
        pool is dry and the engine installed ``evict_cb``, ask the cache
        to give back least-recently-used pages before giving up.  With no
        callback (cache off) this is exactly one ``pool.alloc`` call, so
        fault-injection schedules are unchanged."""
        pages = self.pool.alloc(n)
        if pages is None and self.evict_cb is not None:
            if self.evict_cb(max(1, n - self.pool.num_free)):
                pages = self.pool.alloc(n)
        return pages

    def admit(self, plan=None) -> list[Request]:
        """Admit waiting requests FIFO while a slot and pages are
        available.  By default allocates each admission's prompt pages
        *plus one* growth page worth of headroom (so a request never
        needs a page on its very first decode step), assigns a slot, and
        marks it RUNNING; the engine then prefills the batch it gets
        back.

        ``plan`` (engine-supplied) may redirect a request onto the
        chunked / shared-prefix path: called with the request, it returns
        None for the legacy single-shot route, or ``(shared_pages,
        start_tokens, reserve_pages)`` — the cached pages to map at the
        head of the block table (one :meth:`PagePool.share` reference
        each), the token offset prefill resumes from, and the page count
        to allocate now.  Such admissions enter state PREFILLING and the
        engine advances them chunk by chunk."""
        # parked storm victims rejoin (at the head — they are the oldest
        # work in the system) once the regular queue has drained: by then
        # the mix that was thrashing them has left the pool
        if self.parked and not self.waiting:
            self.waiting.extendleft(reversed(self.parked))
            self.parked.clear()
        admitted = []
        slots = self.free_slots()
        while self.waiting and slots:
            req = self.waiting[0]
            decision = plan(req) if plan is not None else None
            if decision is None:
                need = self.pool.pages_for(len(req.full_sequence) + 1)
                pages = self._alloc(need)
                if pages is None:
                    break                               # strict FIFO
                shared, start = [], 0
                req.state = RequestState.RUNNING
            else:
                shared, start, reserve = decision
                pages = self._alloc(reserve) if reserve else []
                if pages is None:
                    break                               # strict FIFO
                self.pool.share(shared)
                req.state = RequestState.PREFILLING
            self.waiting.popleft()
            req.pages = list(shared) + pages
            req.shared_pages = len(shared)
            req.prefill_done = start
            req.slot = slots.pop(0)
            self.running[req.slot] = req
            self._admitted_at[req.rid] = next(self._admit_seq)
            admitted.append(req)
        return admitted

    def reserve(self, req: Request, n: int) -> list[int] | None:
        """Grant ``req`` ``n`` more pages for its next prefill chunk (no
        preemption here — the engine decides how to handle a dry pool
        mid-prefill).  Appends to ``req.pages`` on success."""
        pages = self._alloc(n)
        if pages is not None:
            req.pages.extend(pages)
        return pages

    # ------------------------------------------------------ page growth

    def grow(self, req: Request) -> bool:
        """Grant ``req`` one more page, preempting younger requests until
        it fits.  False only when ``req`` is alone and the pool is still
        dry — the pool is simply too small for this sequence."""
        while True:
            pages = self._alloc(1)
            if pages is not None:
                req.pages.extend(pages)
                return True
            victim = self._youngest_running(exclude=req)
            if victim is None:
                return False
            self.preempt(victim)

    def _youngest_running(self, exclude: Request) -> Request | None:
        cands = [r for r in self.running.values() if r is not exclude]
        if not cands:
            return None
        return max(cands, key=lambda r: self._admitted_at[r.rid])

    def _release(self, req: Request, verb: str) -> None:
        """Shared teardown: drop the slot binding and free the pages,
        with the residency invariant as a real check (not an assert —
        this is control flow and must survive ``python -O``)."""
        if req.slot not in self.running or self.running[req.slot] is not req:
            raise SchedulerInvariantError(
                f"{verb} of request {req.rid} which is not resident in "
                f"slot {req.slot}")
        del self.running[req.slot]
        self.pool.free(req.pages)
        req.pages = []
        req.slot = None
        # chunked-prefill progress does not survive release: a
        # re-admission replans (and re-matches the prefix cache) cleanly
        req.prefill_done = 0
        req.scratch = None
        req.shared_pages = 0

    def preempt(self, req: Request) -> None:
        """Evict a running request: free its pages, requeue it at the
        FRONT of the waiting queue with generated tokens intact — or park
        it once it has been evicted ``max_preemptions`` times (storm
        detection; see the module docstring)."""
        self._release(req, "preempt")
        req.state = RequestState.WAITING
        req.n_preemptions += 1
        self.n_preemptions += 1
        if (self.max_preemptions is not None
                and req.n_preemptions >= self.max_preemptions):
            self.n_parks += 1
            self.parked.append(req)
        else:
            self.waiting.appendleft(req)

    def unadmit(self, req: Request) -> None:
        """Roll an admission back (prefill failed before any state
        landed): free pages and slot, requeue at the FRONT.  Unlike
        :meth:`preempt` this is not an eviction — it doesn't count
        toward the storm detector."""
        self._release(req, "unadmit")
        req.state = RequestState.WAITING
        self.waiting.appendleft(req)

    # ------------------------------------------------------- completion

    def finish(self, req: Request) -> None:
        """Release a completed request's slot and pages (slot recycling)."""
        self._release(req, "finish")
        req.state = RequestState.FINISHED

    def drop(self, req: Request) -> None:
        """Finish a request that is still queued (waiting or parked) —
        deadline expiry, length-cap purge."""
        if req in self.waiting:
            self.waiting.remove(req)
        elif req in self.parked:
            self.parked.remove(req)
        else:
            raise SchedulerInvariantError(
                f"drop of request {req.rid} which is not queued")
        req.state = RequestState.FINISHED
