"""Copy-on-write prefix cache: page-granular KV sharing across requests.

Real traffic is dominated by shared prompt prefixes (system prompts,
few-shot preambles).  The paged KV cache already stores prompts as
fixed-size pages; this module makes those pages *shareable*: full prompt
pages are hashed into a prefix tree keyed by token-block content, so a
request whose prompt prefix was already prefilled maps the cached pages
read-only — via :meth:`PagePool.share` refcounts — and only computes the
novel tail.

Design points:

  * **content-keyed tree** — each node is one full page of tokens; the
    path from the root encodes the whole prefix, so page ``j`` of a hit is
    guaranteed to hold KV computed under exactly the same preceding
    tokens.  Partial pages are never cached (their KV would be position-
    padded), which bounds a miss to ``< page_size`` recomputed tokens per
    boundary.
  * **copy-on-write** — requests never write shared pages.  The engine
    COW-splits before any write into a page with ``refcount > 1``:
    allocate a private copy, rewrite it from the prefill scratch, drop the
    shared reference.  The cache's pages are therefore immutable.
  * **LRU eviction under pressure** — the cache holds one reference per
    node.  When the pool runs dry, :meth:`evict_for` walks leaf nodes
    (deepest-first within a chain) in least-recently-matched order and
    frees pages only the cache still owns; pages shared with an in-flight
    request are never evicted out from under it.
  * **fault site** ``prefix.lookup`` (:mod:`repro.faults`) — an injected
    fault makes :meth:`match` report a miss, so a poisoned lookup degrades
    to a full prefill (token-identical output; chaos-tested).

The tree is host-side bookkeeping only; device pages live in the engine's
page-pool arrays and move (defrag) via :meth:`remap`.
"""
from __future__ import annotations

from .kv_cache import PagePool


class _Node:
    """One cached full page: its pool index, LRU clock, and children
    keyed by the NEXT page's token tuple."""

    __slots__ = ("page", "last_use", "children", "parent", "key")

    def __init__(self, page: int, parent: "_Node | None", key: tuple):
        self.page = page
        self.last_use = 0
        self.children: dict[tuple, _Node] = {}
        self.parent = parent
        self.key = key


class PrefixCache:
    """Prefix tree over a :class:`PagePool`'s refcounted pages.

    The cache owns one pool reference per node (taken via ``pool.share``
    at :meth:`insert`, dropped via ``pool.free`` at eviction).  ``match``
    returns shared pages *without* adding references — the engine calls
    ``pool.share`` only once it commits to mapping them into a request.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._children: dict[tuple, _Node] = {}        # root level
        self._clock = 0
        self.n_nodes = 0
        self.n_evictions = 0

    # ------------------------------------------------------------ lookup

    def match(self, tokens: list[int]) -> tuple[list[int], int]:
        """Longest cached full-page prefix of ``tokens``.

        Returns ``(pages, matched_tokens)`` with ``matched_tokens ==
        len(pages) * page_size``.  Matched nodes' LRU clocks are touched.
        The ``prefix.lookup`` fault site degrades a poisoned lookup to a
        clean miss — the engine then runs a full prefill.
        """
        from repro import faults
        if faults.poke("prefix.lookup") is not None:
            return [], 0
        ps = self.pool.page_size
        pages: list[int] = []
        children = self._children
        self._clock += 1
        for start in range(0, len(tokens) - ps + 1, ps):
            node = children.get(tuple(tokens[start:start + ps]))
            if node is None:
                break
            node.last_use = self._clock
            pages.append(node.page)
            children = node.children
        return pages, len(pages) * ps

    # ------------------------------------------------------------ insert

    def insert(self, tokens: list[int], pages: list[int]) -> int:
        """Register a prefilled sequence's full pages.

        ``pages[j]`` must hold the KV of ``tokens[j*ps:(j+1)*ps]`` (any
        trailing partial page is ignored).  New nodes take one pool
        reference each; token blocks already cached keep their existing
        page (same content + same prefix ⇒ same KV), and the caller's
        duplicate page simply remains request-owned.  Returns the number
        of nodes created.
        """
        ps = self.pool.page_size
        created = 0
        children = self._children
        parent: _Node | None = None
        self._clock += 1
        for j in range(min(len(tokens) // ps, len(pages))):
            key = tuple(tokens[j * ps:(j + 1) * ps])
            node = children.get(key)
            if node is None:
                node = _Node(pages[j], parent, key)
                self.pool.share([pages[j]])
                children[key] = node
                self.n_nodes += 1
                created += 1
            node.last_use = self._clock
            children = node.children
            parent = node
        return created

    # ---------------------------------------------------------- eviction

    def _leaves(self) -> list[_Node]:
        out = []
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            else:
                out.append(node)
        return out

    def evict_for(self, n: int) -> int:
        """Free up to ``n`` pages by evicting least-recently-matched
        leaves whose pages only the cache still references.  Evicting a
        leaf can expose its parent as the next candidate, so the walk
        repeats until the budget is met or nothing evictable remains.
        Returns the number of pages actually freed."""
        freed = 0
        while freed < n:
            cands = [lf for lf in self._leaves()
                     if self.pool.refcount(lf.page) == 1]
            if not cands:
                break
            victim = min(cands, key=lambda lf: (lf.last_use, -lf.page))
            siblings = (victim.parent.children if victim.parent is not None
                        else self._children)
            del siblings[victim.key]
            self.pool.free([victim.page])
            self.n_nodes -= 1
            self.n_evictions += 1
            freed += 1
        return freed

    # ------------------------------------------------------------ defrag

    def remap(self, mapping: dict[int, int]) -> None:
        """Apply a :meth:`PagePool.defrag` ``{old: new}`` mapping to every
        cached node (shared pages moved once on device; every owner's
        bookkeeping re-points here)."""
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            node.page = mapping[node.page]
            stack.extend(node.children.values())
