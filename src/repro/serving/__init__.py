"""Serving subsystem: paged KV cache, continuous batching, per-request
sampling — the third kernel-backed subsystem after GEMM dispatch and flash
attention.  See docs/serving.md and docs/robustness.md."""
from .engine import Engine
from .errors import (EngineOverloaded, FinishReason, PagePoolError,
                     RequestRejected, RequestResult, SchedulerInvariantError,
                     ServingError)
from .kv_cache import DEFAULT_PAGE_SIZE, PagePool
from .prefix_cache import PrefixCache
from .sampling import SamplingParams
from .scheduler import Request, RequestState, Scheduler

__all__ = ["Engine", "PagePool", "PrefixCache", "SamplingParams", "Request",
           "RequestState", "Scheduler", "DEFAULT_PAGE_SIZE",
           "FinishReason", "RequestResult", "ServingError",
           "RequestRejected", "EngineOverloaded", "SchedulerInvariantError",
           "PagePoolError"]
