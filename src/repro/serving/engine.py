"""Continuous-batching serving engine over the paged KV cache.

The legacy ``launch/serve.py`` loop was a research artifact: same-length
prompts only, prefill via P sequential decode steps, a dense per-batch
cache, one batch-wide sampling mode, and exactly ``gen_len`` tokens for
everyone.  This engine serves a *stream* of requests:

  * **admission**: waiting requests are admitted FIFO whenever a slot and
    enough pages are free (``scheduler.py``); admissions with the same
    padded prompt length prefill together as one batch;
  * **prefill**: ONE jitted sequence-level forward (``models.lm.prefill``,
    through the fused sdpa route) returns last-token logits and every
    layer's K/V, which are scattered into freshly allocated pages — no
    more O(P) decode-step prompt loops;
  * **decode**: one jitted step advances *every* in-flight slot — whatever
    mix of requests, depths, and sampling parameters is resident — through
    ``models.lm.decode_step_paged`` (the paged TCEC kernel via
    ``dispatch.attention_decode`` when eligible, the page-gather fallback
    otherwise) and one vectorized :func:`serving.sampling.sample` call;
  * **completion**: stop tokens / ``max_tokens`` finish a request on the
    host; its slot and pages recycle into the next admission immediately —
    the batch never drains to a barrier;
  * **preemption**: when the pool runs dry, the youngest running request
    is evicted (recompute-style: its pages are freed, its tokens kept) and
    re-admitted later.

Numerics contract (tests/test_serving.py): with the paged kernel hatch
closed (CPU default), greedy engine output is **token-identical** to the
dense-cache ``launch.serve.generate_dense`` path — the page gather feeds
bitwise the same attend as the dense cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import numerics
from repro.models import get_model
from . import sampling
from .kv_cache import (DEFAULT_PAGE_SIZE, PagePool, inverse_permutation,
                       permute_pages, write_prompt_pages)
from .sampling import SamplingParams
from .scheduler import Request, RequestState, Scheduler


def _pool_spec(shape, mesh):
    """PartitionSpec for one page-pool leaf ``(..., Hkv, hd)``: KV heads
    on ``model`` when divisible (the paged plan's layout), else head_dim
    (always a multiple of 16 in the zoo — ``parallel/sharding.py``'s
    cache convention), else replicated."""
    from jax.sharding import PartitionSpec as P
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    dims = [None] * len(shape)
    if msize > 1 and len(shape) >= 2:
        if shape[-2] % msize == 0:
            dims[-2] = "model"
        elif shape[-1] % msize == 0:
            dims[-1] = "model"
    return P(*dims)


class Engine:
    """Continuous-batching engine for the KV-cache model families
    (``dense``/``moe``, including MLA and sliding-window variants).

    max_slots: decode batch width (static — inactive slots are masked).
    num_pages: pool size including the reserved scrap page 0.
    page_size: tokens per page.
    max_pages_per_slot: block-table width; a request that outgrows it is
        finished early (length cap), like any server's max context.
    """

    def __init__(self, cfg, params, *, max_slots: int = 4,
                 num_pages: int | None = None,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 max_pages_per_slot: int | None = None,
                 numerics_config: numerics.NumericsConfig | None = None,
                 mesh=None):
        # the engine's kernel-dispatch recipe is pinned at construction:
        # every jitted step runs under this scope, so an ambient
        # numerics.use(...) entered mid-serve can't flip an in-flight
        # trace's dispatch decisions out from under the KV cache
        self.numerics_config = numerics_config or numerics.active()
        # likewise the mesh: captured from the installed context (or taken
        # explicitly) at construction.  Every jitted step then traces
        # under it, so paged decode routes through the shard_map wrapper
        # (kernels/shmap.py) and the page pools live sharded on device —
        # KV heads on the "model" axis, tables/lengths device-local.
        from repro.parallel import ctx as _pctx
        self.mesh = mesh if mesh is not None else _pctx.current_mesh()
        model = get_model(cfg)
        if model.decode_step_paged is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged decode path; use "
                "launch.serve.generate_dense")
        if num_pages is None:
            num_pages = 1 + max_slots * 32
        if max_pages_per_slot is None:
            max_pages_per_slot = min(64, num_pages - 1)
        self.cfg = cfg
        self.params = params
        self.model = model
        self.pool = PagePool(num_pages, page_size)
        self.sched = Scheduler(self.pool, max_slots)
        self.max_slots = max_slots
        self.max_pages_per_slot = max_pages_per_slot
        self.pools = model.init_paged_cache(num_pages, page_size)
        if self.mesh is not None:
            self.pools = jax.device_put(self.pools, self._pool_shardings())
        # host mirrors of the per-slot device state
        self.block_tables = np.zeros((max_slots, max_pages_per_slot),
                                     np.int32)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.next_tok = np.zeros((max_slots,), np.int32)
        self.temps = np.zeros((max_slots,), np.float32)
        self.topks = np.zeros((max_slots,), np.int32)
        self.topps = np.ones((max_slots,), np.float32)
        self.keys = jnp.zeros((max_slots, 2), jnp.uint32)
        self._requests: dict[int, Request] = {}
        # donate the pool buffers (arg 1): every step rebinds self.pools,
        # so off-CPU the page update runs in place instead of copying the
        # whole cache per token (CPU XLA lacks donation and would warn)
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._decode = jax.jit(functools.partial(_decode_and_sample,
                                                 model=model, cfg=cfg),
                               donate_argnums=donate)
        self._prefill = jax.jit(lambda p, toks: model.prefill(p, toks))
        self.n_decode_steps = 0
        self.n_prefills = 0

    def _pool_shardings(self):
        """Multi-device pool layout: shard each page pool's KV-head dim
        (axis -2) on the ``model`` axis when it divides — the same layout
        ``kernels/shmap.py``'s paged plan shards the kernel over, so the
        decode step never reshards the cache.  When the head count does
        not divide (kv_heads < model size), fall back to sharding head_dim
        (axis -1) — the KV-cache convention of ``parallel/sharding.py`` —
        so pool capacity still scales with TP; the fused kernel declines
        for that layout and the XLA gather fallback carries the sharding.
        Everything else (page and token dims) stays replicated."""
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda leaf: NamedSharding(
                self.mesh, _pool_spec(leaf.shape, self.mesh)),
            self.pools)

    def _scopes(self):
        """The construction-pinned numerics + mesh scopes every engine
        step (prefill and decode) runs under."""
        import contextlib
        from repro.parallel import ctx as _pctx
        scope = contextlib.ExitStack()
        scope.enter_context(numerics.use(self.numerics_config))
        if self.mesh is not None:
            scope.enter_context(_pctx.use_mesh(self.mesh))
        return scope

    # ------------------------------------------------------------ intake

    def add_request(self, prompt, params: SamplingParams | None = None) -> int:
        params = params or SamplingParams()
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        assert params.max_tokens >= 1
        need = self.pool.pages_for(len(prompt) + 1)
        if need > min(self.max_pages_per_slot, self.pool.num_pages - 1):
            raise ValueError(f"prompt needs {need} pages; engine caps at "
                             f"{self.max_pages_per_slot} per slot")
        req = self.sched.add(prompt, params)
        req.key = jax.random.PRNGKey(params.seed)
        self._requests[req.rid] = req
        return req.rid

    # ----------------------------------------------------------- prefill

    def _admit_and_prefill(self):
        # a preempted request may have *generated* its way past the per-slot
        # cap (add_request only guards prompts): finish it from the queue —
        # re-admitting would need more pages than a block-table row holds
        for req in [r for r in self.sched.waiting
                    if self.pool.pages_for(len(r.full_sequence) + 1)
                    > self.max_pages_per_slot]:
            self.sched.waiting.remove(req)
            req.state = RequestState.FINISHED
        admitted = self.sched.admit()
        ps = self.pool.page_size
        # same padded length -> one batched prefill call
        groups: dict[int, list[Request]] = {}
        for req in admitted:
            seq = req.full_sequence
            padded = max(1, -(-len(seq) // ps)) * ps
            groups.setdefault(padded, []).append(req)
        for padded, reqs in sorted(groups.items()):
            toks = np.zeros((len(reqs), padded), np.int32)
            for i, req in enumerate(reqs):
                toks[i, :len(req.full_sequence)] = req.full_sequence
            logits, kv = self._prefill(self.params, jnp.asarray(toks))
            self.n_prefills += 1
            n_prompt_pages = padded // ps
            pages = np.asarray([req.pages[:n_prompt_pages] for req in reqs],
                               np.int32)
            self.pools = write_prompt_pages(self.pools, kv,
                                            jnp.asarray(pages))
            for i, req in enumerate(reqs):
                plen = len(req.full_sequence)
                self.lengths[req.slot] = plen
                self._sync_slot(req)
                row = jnp.asarray(logits[i, plen - 1,
                                         :self.cfg.vocab_size], jnp.float32)
                req.key, sub = jax.random.split(req.key)
                tok = int(sampling.sample_one(row, req.params, sub))
                self._accept_token(req, tok)

    def _sync_slot(self, req: Request):
        """Push a request's page list and sampling knobs into its slot."""
        s = req.slot
        self.block_tables[s] = 0
        self.block_tables[s, :len(req.pages)] = req.pages
        self.temps[s] = req.params.temperature
        self.topks[s] = req.params.top_k
        self.topps[s] = req.params.top_p
        self.keys = self.keys.at[s].set(req.key)

    def _clear_slot(self, slot: int):
        self.block_tables[slot] = 0
        self.lengths[slot] = 0
        self.next_tok[slot] = 0
        self.temps[slot] = 0.0
        self.topks[slot] = 0
        self.topps[slot] = 1.0

    def _accept_token(self, req: Request, tok: int) -> bool:
        """Host-side completion logic; returns True while still running."""
        if tok in req.params.stop_tokens:
            self._finish(req)
            return False
        req.out.append(tok)
        if len(req.out) >= req.params.max_tokens:
            self._finish(req)
            return False
        self.next_tok[req.slot] = tok
        return True

    def _finish(self, req: Request):
        slot = req.slot
        self.sched.finish(req)
        self._clear_slot(slot)

    # ------------------------------------------------------------ decode

    def _ensure_pages(self):
        """Every running slot must own the page its next token writes to;
        grow (possibly preempting) before the step, not during it."""
        ps = self.pool.page_size
        for req in sorted(self.sched.running.values(),
                          key=lambda r: self.sched._admitted_at[r.rid]):
            if req.slot is None:        # preempted by an earlier grow
                continue
            page_idx = int(self.lengths[req.slot]) // ps
            if page_idx >= self.max_pages_per_slot:
                self._finish(req)       # hit the per-slot length cap
                continue
            if page_idx >= len(req.pages):
                before = {r.rid: r.slot for r in self.sched.running.values()}
                if not self.sched.grow(req):
                    raise RuntimeError(
                        "page pool too small for a single request")
                for rid, slot in before.items():
                    r = self._requests[rid]
                    if r.slot is None:          # got preempted: mask slot
                        self._clear_slot(slot)
                self.block_tables[req.slot] = 0
                self.block_tables[req.slot, :len(req.pages)] = req.pages

    def _decode_step(self):
        running = [r for r in self.sched.running.values()]
        if not running:
            return
        toks, self.pools, self.keys = self._decode(
            self.params, self.pools, jnp.asarray(self.block_tables),
            jnp.asarray(self.lengths), jnp.asarray(self.next_tok),
            jnp.asarray(self.temps), jnp.asarray(self.topks),
            jnp.asarray(self.topps), self.keys)
        self.n_decode_steps += 1
        toks = np.asarray(toks)
        for req in running:
            self.lengths[req.slot] += 1      # its input token is now cached
            req.key = self.keys[req.slot]
            self._accept_token(req, int(toks[req.slot]))

    # ------------------------------------------------------------- drive

    def step(self):
        """One engine iteration: admit + prefill, then one decode step for
        whatever is in flight — under the construction-time numerics and
        mesh scopes."""
        with self._scopes():
            self._admit_and_prefill()
            self._ensure_pages()
            self._decode_step()

    def run(self, prompts=None, params=None) -> dict[int, list[int]]:
        """Convenience driver: optionally enqueue ``prompts`` (with one
        :class:`SamplingParams` each, or one shared), run to drain, and
        return ``{rid: generated tokens}`` for everything enqueued since
        construction."""
        if prompts is not None:
            if params is None:
                params = [None] * len(prompts)
            elif isinstance(params, SamplingParams):
                params = [params] * len(prompts)
            for prompt, sp in zip(prompts, params):
                self.add_request(prompt, sp)
        while self.sched.has_work:
            self.step()
        return {rid: list(req.out) for rid, req in self._requests.items()}

    # ------------------------------------------------------------ defrag

    def defragment(self):
        """Compact live pages to the low end of the pool: permutes the
        device page arrays and re-indexes every running request's block
        table.  Safe between steps; output-invariant (tests assert)."""
        mapping = self.pool.defrag()
        perm = inverse_permutation(mapping, self.pool.num_pages)
        self.pools = permute_pages(self.pools, perm)
        for req in self.sched.running.values():
            req.pages = [mapping[p] for p in req.pages]
            self.block_tables[req.slot] = 0
            self.block_tables[req.slot, :len(req.pages)] = req.pages


def _decode_and_sample(params, pools, block_tables, lengths, toks, temps,
                       topks, topps, keys, *, model, cfg):
    """The jitted engine step: paged model decode + vectorized sampling +
    per-slot key advance, one dispatch for the whole slot array."""
    logits, new_pools = model.decode_step_paged(params, pools, block_tables,
                                                lengths, toks)
    logits = logits[:, :cfg.vocab_size].astype(jnp.float32)
    # split convention must match the prefill draw (`key, sub = split(key)`:
    # carry row 0, sample with row 1) — otherwise a preemption's re-prefill
    # would resume a request's stream on the wrong side of the split
    split = jax.vmap(jax.random.split)(keys)          # (B, 2, 2)
    out = sampling.sample(logits, temps, topks, topps, split[:, 1])
    return out, new_pools, split[:, 0]
