"""Continuous-batching serving engine over the paged KV cache.

The legacy ``launch/serve.py`` loop was a research artifact: same-length
prompts only, prefill via P sequential decode steps, a dense per-batch
cache, one batch-wide sampling mode, and exactly ``gen_len`` tokens for
everyone.  This engine serves a *stream* of requests:

  * **admission**: waiting requests are admitted FIFO whenever a slot and
    enough pages are free (``scheduler.py``); admissions with the same
    padded prompt length prefill together as one batch;
  * **prefill**: ONE jitted sequence-level forward (``models.lm.prefill``,
    through the fused sdpa route) returns last-token logits and every
    layer's K/V, which are scattered into freshly allocated pages — no
    more O(P) decode-step prompt loops;
  * **decode**: one jitted step advances *every* in-flight slot — whatever
    mix of requests, depths, and sampling parameters is resident — through
    ``models.lm.decode_step_paged`` (the paged TCEC kernel via
    ``dispatch.attention_decode`` when eligible, the page-gather fallback
    otherwise) and one vectorized :func:`serving.sampling.sample` call;
  * **completion**: stop tokens / ``max_tokens`` finish a request on the
    host; its slot and pages recycle into the next admission immediately —
    the batch never drains to a barrier;
  * **preemption**: when the pool runs dry, the youngest running request
    is evicted (recompute-style: its pages are freed, its tokens kept) and
    re-admitted later.

Numerics contract (tests/test_serving.py): with the paged kernel hatch
closed (CPU default), greedy engine output is **token-identical** to the
dense-cache ``launch.serve.generate_dense`` path — the page gather feeds
bitwise the same attend as the dense cache.

Resilience contract (docs/robustness.md, tests/test_faults.py): requests
finish with a :class:`~repro.serving.errors.FinishReason`; admission is
bounded (``max_waiting`` -> :class:`EngineOverloaded`) and validated
(:class:`RequestRejected`); per-request deadlines are enforced against
the engine's step clock; the jitted decode step returns a per-slot
``isfinite`` guard bit, and a tripped step re-runs ONCE under the
XLA-fallback numerics scope before any slot is failed with
``finish_reason="error"``; a preemption storm parks its victims
(``max_preemptions``) instead of livelocking.  Every recovery path is
fault-injectable via :mod:`repro.faults`.
"""
from __future__ import annotations

import contextlib
import functools
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, numerics
from repro.models import get_model
from repro.obs import metrics as _obs_metrics
from repro.obs.trace import current as _current_tracer
from . import sampling
from .errors import (EngineOverloaded, FinishReason, RequestRejected,
                     RequestResult)
from .kv_cache import (DEFAULT_PAGE_SIZE, PagePool, inverse_permutation,
                       load_pages_into_scratch, permute_pages,
                       write_prompt_pages, write_span_pages)
from .prefix_cache import PrefixCache
from .sampling import SamplingParams
from .scheduler import Request, RequestState, Scheduler


# live engines, summed into repro.obs snapshots at read time (weak refs:
# registering here never keeps a dropped engine's cache pools alive)
_LIVE_ENGINES: "weakref.WeakSet[Engine]" = weakref.WeakSet()


def _engines_source() -> dict:
    out: dict[str, int] = {}
    for eng in list(_LIVE_ENGINES):
        stats = {**eng._stats, "clock": eng.clock,
                 "prefills": eng.n_prefills,
                 "prefill_chunks": eng.n_prefill_chunks,
                 "decode_steps": eng.n_decode_steps,
                 "preemptions": eng.sched.n_preemptions,
                 "parks": eng.sched.n_parks}
        for k, v in stats.items():
            out[k] = out.get(k, 0) + int(v)
    return out


_obs_metrics.register_source("serving/engine", _engines_source)


def _pool_spec(shape, mesh):
    """PartitionSpec for one page-pool leaf ``(..., Hkv, hd)``: KV heads
    on ``model`` when divisible (the paged plan's layout), else head_dim
    (always a multiple of 16 in the zoo — ``parallel/sharding.py``'s
    cache convention), else replicated."""
    from jax.sharding import PartitionSpec as P
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    dims = [None] * len(shape)
    if msize > 1 and len(shape) >= 2:
        if shape[-2] % msize == 0:
            dims[-2] = "model"
        elif shape[-1] % msize == 0:
            dims[-1] = "model"
    return P(*dims)


class Engine:
    """Continuous-batching engine for the KV-cache model families
    (``dense``/``moe``, including MLA and sliding-window variants).

    max_slots: decode batch width (static — inactive slots are masked).
    num_pages: pool size including the reserved scrap page 0.
    page_size: tokens per page.
    max_pages_per_slot: block-table width; a request that outgrows it is
        finished early (length cap), like any server's max context.
    max_waiting: waiting-queue bound; ``add_request`` past it raises
        :class:`EngineOverloaded` (None = unbounded).
    max_preemptions: evictions before a request is parked as a
        preemption-storm victim (None = never park).
    cache_dtype: page-pool element dtype (None = the family default,
        bfloat16).  The shared-prefix parity contract needs float32: a
        reused page's K/V must be bitwise what a fresh prefill would
        compute, and the bf16 round-trip loses that.

    Three serving knobs ride on the pinned
    :class:`~repro.numerics.NumericsConfig` (``REPRO_PREFIX_CACHE``,
    ``REPRO_CHUNKED_PREFILL``, ``REPRO_ASYNC_SCHED``); all default off,
    and with all three off every code path below is byte-identical to
    the legacy single-shot engine.
    """

    def __init__(self, cfg, params, *, max_slots: int = 4,
                 num_pages: int | None = None,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 max_pages_per_slot: int | None = None,
                 max_waiting: int | None = None,
                 max_preemptions: int | None = 8,
                 numerics_config: numerics.NumericsConfig | None = None,
                 cache_dtype=None,
                 mesh=None):
        # the engine's kernel-dispatch recipe is pinned at construction:
        # every jitted step runs under this scope, so an ambient
        # numerics.use(...) entered mid-serve can't flip an in-flight
        # trace's dispatch decisions out from under the KV cache
        self.numerics_config = numerics_config or numerics.active()
        # likewise the mesh: captured from the installed context (or taken
        # explicitly) at construction.  Every jitted step then traces
        # under it, so paged decode routes through the shard_map wrapper
        # (kernels/shmap.py) and the page pools live sharded on device —
        # KV heads on the "model" axis, tables/lengths device-local.
        from repro.parallel import ctx as _pctx
        self.mesh = mesh if mesh is not None else _pctx.current_mesh()
        model = get_model(cfg)
        if model.decode_step_paged is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged decode path; use "
                "launch.serve.generate_dense")
        if num_pages is None:
            num_pages = 1 + max_slots * 32
        if max_pages_per_slot is None:
            max_pages_per_slot = min(64, num_pages - 1)
        self.cfg = cfg
        self.params = params
        self.model = model
        self.pool = PagePool(num_pages, page_size)
        self.sched = Scheduler(self.pool, max_slots,
                               max_preemptions=max_preemptions)
        self.max_slots = max_slots
        self.max_pages_per_slot = max_pages_per_slot
        self.max_waiting = max_waiting
        # the deadline clock: one tick per step() (plus injected
        # decode.slow penalties) — deterministic, no wall-clock reads
        self.clock = 0
        # the one-shot re-run recipe for non-finite decode steps: same
        # policy math on the XLA term-expansion path, no fused kernels
        self._fallback_numerics = self.numerics_config.replace(enabled=False)
        self._stats = {"guard_trips": 0, "fallback_reruns": 0,
                       "numerics_errors": 0, "rejections": 0, "overloads": 0,
                       "timeouts": 0, "length_caps": 0, "prefill_faults": 0,
                       "prefix_hits": 0, "prefix_tokens_reused": 0,
                       "cow_splits": 0, "prefix_evictions": 0}
        kw = {} if cache_dtype is None else {"dtype": cache_dtype}
        self.pools = model.init_paged_cache(num_pages, page_size, **kw)
        if self.mesh is not None:
            self.pools = jax.device_put(self.pools, self._pool_shardings())
        # host mirrors of the per-slot device state
        self.block_tables = np.zeros((max_slots, max_pages_per_slot),
                                     np.int32)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.next_tok = np.zeros((max_slots,), np.int32)
        self.temps = np.zeros((max_slots,), np.float32)
        self.topks = np.zeros((max_slots,), np.int32)
        self.topps = np.ones((max_slots,), np.float32)
        self.keys = jnp.zeros((max_slots, 2), jnp.uint32)
        self._requests: dict[int, Request] = {}
        # donate the pool buffers (arg 1): every step rebinds self.pools,
        # so off-CPU the page update runs in place instead of copying the
        # whole cache per token (CPU XLA lacks donation and would warn)
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._decode = jax.jit(functools.partial(_decode_and_sample,
                                                 model=model, cfg=cfg),
                               donate_argnums=donate)
        self._prefill = jax.jit(lambda p, toks: model.prefill(p, toks))
        self._prefill_chunk = jax.jit(
            lambda p, cache, toks, start: model.prefill_chunk(
                p, cache, toks, start))
        # serving knobs ride on the pinned numerics config; chunk size is
        # rounded UP to a page multiple so every chunk boundary is also a
        # page boundary (the span scatter stays whole-page)
        self.chunk_tokens = 0
        if self.numerics_config.chunked_prefill > 0:
            self.chunk_tokens = (-(-self.numerics_config.chunked_prefill
                                   // page_size)) * page_size
        self.async_sched = bool(self.numerics_config.async_sched)
        self.prefix = (PrefixCache(self.pool)
                       if self.numerics_config.prefix_cache else None)
        if self.prefix is not None:
            self.sched.evict_cb = self._evict_prefix
        # async overlap: the dispatched-but-unconsumed decode step, plus
        # double-buffered host staging for its integer/float inputs (the
        # mirrors may be mutated for step N+1 while step N is in flight)
        self._inflight = None
        self._staging = [
            {name: np.zeros_like(getattr(self, name))
             for name in ("block_tables", "lengths", "next_tok",
                          "temps", "topks", "topps")}
            for _ in range(2)]
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_prefill_chunks = 0
        _LIVE_ENGINES.add(self)

    def _pool_shardings(self):
        """Multi-device pool layout: shard each page pool's KV-head dim
        (axis -2) on the ``model`` axis when it divides — the same layout
        ``kernels/shmap.py``'s paged plan shards the kernel over, so the
        decode step never reshards the cache.  When the head count does
        not divide (kv_heads < model size), fall back to sharding head_dim
        (axis -1) — the KV-cache convention of ``parallel/sharding.py`` —
        so pool capacity still scales with TP; the fused kernel declines
        for that layout and the XLA gather fallback carries the sharding.
        Everything else (page and token dims) stays replicated."""
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda leaf: NamedSharding(
                self.mesh, _pool_spec(leaf.shape, self.mesh)),
            self.pools)

    def _scopes(self):
        """The construction-pinned numerics + mesh scopes every engine
        step (prefill and decode) runs under."""
        import contextlib
        from repro.parallel import ctx as _pctx
        scope = contextlib.ExitStack()
        scope.enter_context(numerics.use(self.numerics_config))
        if self.mesh is not None:
            scope.enter_context(_pctx.use_mesh(self.mesh))
        return scope

    # ------------------------------------------------------- observability
    #
    # Everything below is gated on an active repro.obs tracer: with no
    # trace() context installed there are no spans, no wall-clock reads,
    # and no histogram writes — the engine's hot loop is unchanged (the
    # overhead test pins zero extra jitted traces with tracing off).

    def _span(self, name: str, **args):
        """A tracer span around one engine phase, or a no-op context
        yielding a throwaway args dict when tracing is off."""
        tr = _current_tracer()
        if tr is None:
            return contextlib.nullcontext(dict(args))
        return tr.span(name, cat="engine", **args)

    @staticmethod
    def _observe_latency(name: str, seconds: float):
        _obs_metrics.observe(f"serving/latency/{name}", seconds)

    def _trace_request_end(self, req: Request):
        tr = _current_tracer()
        if tr is not None:
            tr.async_end("request", req.rid, finish=req.finish_reason,
                         tokens=len(req.out))

    def _trace_preempt(self, req: Request):
        tr = _current_tracer()
        if tr is not None:
            tr.async_instant("preempted", req.rid,
                             n_preemptions=req.n_preemptions)

    # ------------------------------------------------------------ intake

    def add_request(self, prompt, params: SamplingParams | None = None,
                    deadline: int | None = None) -> int:
        """Enqueue a request; returns its rid.

        Raises :class:`RequestRejected` for requests that can never be
        served and :class:`EngineOverloaded` when the waiting queue is at
        ``max_waiting`` (backpressure — retry later).  ``deadline`` is a
        step budget: the request must finish within that many engine
        clock ticks or it is timed out (``finish_reason="timeout"``).
        """
        params = params or SamplingParams()
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if params.max_tokens < 1:                # was an assert: -O-unsafe
            self._stats["rejections"] += 1
            raise RequestRejected(
                f"max_tokens must be >= 1, got {params.max_tokens}")
        need = self.pool.pages_for(len(prompt) + 1)
        if need > min(self.max_pages_per_slot, self.pool.num_pages - 1):
            self._stats["rejections"] += 1
            raise RequestRejected(
                f"prompt needs {need} pages; engine caps at "
                f"{self.max_pages_per_slot} per slot")
        if deadline is not None and deadline < 1:
            self._stats["rejections"] += 1
            raise RequestRejected(f"deadline must be >= 1, got {deadline}")
        if (self.max_waiting is not None
                and len(self.sched.waiting) >= self.max_waiting):
            self._stats["overloads"] += 1
            raise EngineOverloaded(
                f"waiting queue is at max_waiting={self.max_waiting}")
        req = self.sched.add(prompt, params)
        req.key = jax.random.PRNGKey(params.seed)
        if deadline is not None:
            req.deadline = self.clock + deadline
        self._requests[req.rid] = req
        tr = _current_tracer()
        if tr is not None:
            req.t_enqueue = tr.now()
            tr.async_begin("request", req.rid, prompt_len=len(prompt),
                           max_tokens=params.max_tokens)
        return req.rid

    # ----------------------------------------------------------- prefill

    def _admit_and_prefill(self):
        # a preempted request may have *generated* its way past the per-slot
        # cap (add_request only guards prompts): finish it from the queue —
        # re-admitting would need more pages than a block-table row holds
        for req in [r for r in list(self.sched.waiting) + list(self.sched.parked)
                    if self.pool.pages_for(len(r.full_sequence) + 1)
                    > min(self.max_pages_per_slot, self.pool.num_pages - 1)]:
            self._stats["length_caps"] += 1
            req.finish_reason = FinishReason.LENGTH_CAP.value
            self.sched.drop(req)
            self._trace_request_end(req)
        plan = (self._plan_admission
                if (self.prefix is not None or self.chunk_tokens) else None)
        admitted = self.sched.admit(plan)
        for req in admitted:
            if req.state is RequestState.PREFILLING:
                if req.shared_pages:
                    self._stats["prefix_hits"] += 1
                    self._stats["prefix_tokens_reused"] += req.prefill_done
                self._start_chunked_prefill(req)
        tr = _current_tracer()
        if tr is not None:
            now = tr.now()
            for req in admitted:
                tr.async_instant("admitted", req.rid, clock=self.clock)
                if req.t_enqueue is not None and req.n_preemptions == 0:
                    self._observe_latency("queue_wait_s",
                                          now - req.t_enqueue)
        ps = self.pool.page_size
        # same padded length -> one batched prefill call (PREFILLING
        # admissions advance chunk-by-chunk in _prefill_chunk_step instead)
        groups: dict[int, list[Request]] = {}
        for req in admitted:
            if req.state is not RequestState.RUNNING:
                continue
            seq = req.full_sequence
            padded = max(1, -(-len(seq) // ps)) * ps
            groups.setdefault(padded, []).append(req)
        for padded, reqs in sorted(groups.items()):
            with self._span("prefill", batch=len(reqs), padded=padded):
                toks = np.zeros((len(reqs), padded), np.int32)
                for i, req in enumerate(reqs):
                    toks[i, :len(req.full_sequence)] = req.full_sequence
                try:
                    faults.raise_if("prefill")
                    logits, kv = self._prefill(self.params, jnp.asarray(toks))
                except Exception as exc:  # noqa: BLE001 — rolled back below
                    self._on_prefill_failure(reqs, exc)
                    continue
                self.n_prefills += 1
                n_prompt_pages = padded // ps
                pages = np.asarray([req.pages[:n_prompt_pages]
                                    for req in reqs], np.int32)
                self.pools = write_prompt_pages(self.pools, kv,
                                                jnp.asarray(pages))
                if self.prefix is not None:
                    # register full pages before the accept loop: a request
                    # finishing on its first token frees its own refs, but
                    # the tree's references keep the pages alive
                    for req in reqs:
                        self.prefix.insert(req.full_sequence, req.pages)
                for i, req in enumerate(reqs):
                    plen = len(req.full_sequence)
                    self.lengths[req.slot] = plen
                    self._sync_slot(req)
                    row = jnp.asarray(logits[i, plen - 1,
                                             :self.cfg.vocab_size],
                                      jnp.float32)
                    req.key, sub = jax.random.split(req.key)
                    tok = int(sampling.sample_one(row, req.params, sub))
                    self._accept_token(req, tok)

    # a request whose prefill fails this many times finishes with
    # finish_reason="error" instead of retrying forever
    MAX_PREFILL_FAULTS = 3

    def _on_prefill_failure(self, reqs: list[Request], exc: Exception):
        """Roll a failed prefill group back: nothing landed on device yet
        (the failure happened before ``write_prompt_pages``), so each
        request is un-admitted back to the head of the queue for a clean
        retry next step.  Persistent failers finish with
        ``finish_reason="error"`` after :data:`MAX_PREFILL_FAULTS`
        attempts.  Real (non-injected) errors propagate when the guard
        knob is off."""
        if (not isinstance(exc, faults.FaultInjected)
                and not self.numerics_config.guard):
            raise exc
        self._stats["prefill_faults"] += 1
        # reversed: appendleft-ing restores the group's original FIFO order
        for req in reversed(reqs):
            req.n_prefill_faults += 1
            if req.n_prefill_faults >= self.MAX_PREFILL_FAULTS:
                self._stats["numerics_errors"] += 1
                self._finish(req, FinishReason.ERROR)
            else:
                self.sched.unadmit(req)

    # ------------------------------------- shared prefixes / chunked prefill

    def _evict_prefix(self, n: int) -> int:
        """Scheduler eviction hook: reclaim ``n`` pages from the prefix
        cache's LRU tail when the pool runs dry."""
        freed = self.prefix.evict_for(n)
        self._stats["prefix_evictions"] += freed
        return freed

    def _plan_admission(self, req: Request):
        """Admission plan for :meth:`Scheduler.admit` when the prefix
        cache and/or chunked prefill is on.

        Returns None for the legacy single-shot route, else ``(shared,
        start, reserve)``: the cached pages to map at the head of the
        block table, the token offset prefill resumes from, and how many
        pages to allocate for the first chunk's span.  The last prompt
        position is always recomputed (its logits seed the first sampled
        token), so a full-prompt hit still rewrites the final page — the
        deterministic copy-on-write trigger.
        """
        ps = self.pool.page_size
        seq = req.full_sequence
        plen = len(seq)
        padded = max(1, -(-plen // ps)) * ps
        shared, start = [], 0
        if self.prefix is not None:
            pages, matched = self.prefix.match(seq)
            hit = min(matched, plen - 1)
            # resume on the chunk grid; the overlap [start, matched) is
            # recomputed bitwise-identically and COW-splits its pages
            grid = self.chunk_tokens or ps
            start = (hit // grid) * grid
            shared = pages if start > 0 else []
            if not shared:
                start = 0
        if not shared and not (self.chunk_tokens
                               and plen > self.chunk_tokens):
            return None
        end = (min(start + self.chunk_tokens, padded)
               if self.chunk_tokens else padded)
        reserve = max(0, -(-end // ps) - len(shared))
        return shared, start, reserve

    def _start_chunked_prefill(self, req: Request):
        """Set up a PREFILLING admission: a per-request float32 dense
        scratch cache sized to the chunk grid, pre-populated with the
        shared prefix's K/V.  Chunk attention reads earlier chunks' exact
        f32 values from here, so the math matches a monolithic prefill
        bitwise; only finished whole pages are scattered to the pool."""
        ps = self.pool.page_size
        plen = len(req.full_sequence)
        padded = max(1, -(-plen // ps)) * ps
        T = padded
        if self.chunk_tokens:
            T = (-(-padded // self.chunk_tokens)) * self.chunk_tokens
        req.scratch = self.model.init_cache(1, T, dtype=jnp.float32)
        n_load = req.prefill_done // ps
        if n_load:
            req.scratch = load_pages_into_scratch(
                req.scratch, self.pools,
                jnp.asarray(req.pages[:n_load], jnp.int32))

    def _preempt_prefilling(self, req: Request):
        """A dry pool mid-chunk: recompute-preempt the request itself (a
        re-admission replans, re-matching the prefix cache cleanly) —
        unless the pool could never hold it, which finishes it instead of
        livelocking."""
        slot = req.slot
        if len(req.pages) + 1 >= self.pool.num_pages:
            self._finish(req, FinishReason.ERROR)
            return
        self.sched.preempt(req)
        self._clear_slot(slot)
        self._trace_preempt(req)

    def _prefill_chunk_step(self):
        """Advance every PREFILLING request by one chunk (admission
        order), interleaved with the batched decode step — a long prompt
        no longer stalls every resident decode for its whole prefill, and
        two prefix hits admitted together both emit their first token in
        the admission step, like the monolithic batched path."""
        cands = sorted((r for r in self.sched.running.values()
                        if r.state is RequestState.PREFILLING),
                       key=lambda r: self.sched._admitted_at[r.rid])
        for req in cands:
            if not self._advance_chunk(req):
                return

    def _advance_chunk(self, req: Request) -> bool:
        """One chunk of one request; False stops this step's chunk phase
        (pool pressure or an injected fault — retry next step)."""
        ps = self.pool.page_size
        seq = req.full_sequence
        plen = len(seq)
        padded = max(1, -(-plen // ps)) * ps
        start = req.prefill_done
        C = self.chunk_tokens or (padded - start)
        with self._span("prefill.chunk", rid=req.rid, start=start, chunk=C):
            # pages this chunk scatters back: whole pages in
            # [start, min(start+C, padded)) — the grid-rounded final
            # chunk's pure-padding tail is never materialized
            span_lo = start // ps
            span_hi = -(-min(start + C, padded) // ps)
            need = span_hi - len(req.pages)
            if need > 0 and self.sched.reserve(req, need) is None:
                self._preempt_prefilling(req)
                return False
            # copy-on-write: never write a page someone else references
            for idx in range(span_lo, span_hi):
                if self.pool.refcount(req.pages[idx]) > 1:
                    got = self.sched._alloc(1)
                    if got is None:
                        self._preempt_prefilling(req)
                        return False
                    old = req.pages[idx]
                    req.pages[idx] = got[0]
                    self.pool.free([old])
                    self._stats["cow_splits"] += 1
            toks = np.zeros((1, C), np.int32)
            toks[0, :min(plen, start + C) - start] = seq[start:start + C]
            try:
                faults.raise_if("prefill.chunk")
                logits, req.scratch = self._prefill_chunk(
                    self.params, req.scratch, jnp.asarray(toks),
                    jnp.int32(start))
            except Exception as exc:  # noqa: BLE001 — rolled back below
                self._on_prefill_failure([req], exc)
                return False
            self.n_prefill_chunks += 1
            self.pools = write_span_pages(
                self.pools, req.scratch, jnp.int32(start),
                jnp.asarray(req.pages[span_lo:span_hi], jnp.int32))
            req.prefill_done = start + C
            if req.prefill_done < padded:
                return True
            # prompt fully prefilled: this chunk contains position
            # plen-1, whose logits seed the first sampled token (same
            # draw convention as the monolithic path)
            req.scratch = None
            req.state = RequestState.RUNNING
            if self.prefix is not None:
                self.prefix.insert(seq, req.pages)
            self.lengths[req.slot] = plen
            self._sync_slot(req)
            row = jnp.asarray(logits[0, plen - 1 - start,
                                     :self.cfg.vocab_size], jnp.float32)
            req.key, sub = jax.random.split(req.key)
            tok = int(sampling.sample_one(row, req.params, sub))
            self._accept_token(req, tok)
        return True

    def _sync_slot(self, req: Request):
        """Push a request's page list and sampling knobs into its slot."""
        s = req.slot
        self.block_tables[s] = 0
        self.block_tables[s, :len(req.pages)] = req.pages
        self.temps[s] = req.params.temperature
        self.topks[s] = req.params.top_k
        self.topps[s] = req.params.top_p
        self.keys = self.keys.at[s].set(req.key)

    def _clear_slot(self, slot: int):
        self.block_tables[slot] = 0
        self.lengths[slot] = 0
        self.next_tok[slot] = 0
        self.temps[slot] = 0.0
        self.topks[slot] = 0
        self.topps[slot] = 1.0

    def _accept_token(self, req: Request, tok: int) -> bool:
        """Host-side completion logic; returns True while still running."""
        tr = _current_tracer()
        if tr is not None and req.t_enqueue is not None:
            now = tr.now()
            if req.t_last_token is None:
                self._observe_latency("ttft_s", now - req.t_enqueue)
            else:
                self._observe_latency("tpot_s", now - req.t_last_token)
            req.t_last_token = now
        if tok in req.params.stop_tokens:
            self._finish(req, FinishReason.STOP)
            return False
        req.out.append(tok)
        if len(req.out) >= req.params.max_tokens:
            self._finish(req, FinishReason.LENGTH)
            return False
        self.next_tok[req.slot] = tok
        return True

    def _finish(self, req: Request, reason: FinishReason):
        req.finish_reason = (reason.value if isinstance(reason, FinishReason)
                             else str(reason))
        slot = req.slot
        self.sched.finish(req)
        self._clear_slot(slot)
        self._trace_request_end(req)

    # ------------------------------------------------------------ decode

    def _ensure_pages(self):
        """Every running slot must own the page its next token writes to;
        grow (possibly preempting) before the step, not during it."""
        ps = self.pool.page_size
        for req in sorted(self.sched.running.values(),
                          key=lambda r: self.sched._admitted_at[r.rid]):
            if req.slot is None:        # preempted by an earlier grow
                continue
            if req.state is not RequestState.RUNNING:
                continue                # PREFILLING: pages come per chunk
            page_idx = int(self.lengths[req.slot]) // ps
            if page_idx >= self.max_pages_per_slot:
                self._stats["length_caps"] += 1
                self._finish(req, FinishReason.LENGTH_CAP)
                continue
            if page_idx >= len(req.pages):
                before = {r.rid: r.slot for r in self.sched.running.values()}
                if not self.sched.grow(req):
                    slot = req.slot
                    if len(req.pages) + 1 >= self.pool.num_pages:
                        # the pool cannot hold even this one request:
                        # finish it gracefully (its tokens so far are
                        # still valid) instead of crashing the engine
                        self._finish(req, FinishReason.ERROR)
                    else:
                        # transient exhaustion (an injected alloc fault,
                        # or pages freed off-schedule): requeue and retry
                        # — recompute-preemption of self, not a failure
                        self.sched.preempt(req)
                        self._clear_slot(slot)
                        self._trace_preempt(req)
                    for rid, s in before.items():
                        r = self._requests[rid]
                        if r.slot is None and rid != req.rid:
                            self._clear_slot(s)
                            self._trace_preempt(r)
                    continue
                for rid, slot in before.items():
                    r = self._requests[rid]
                    if r.slot is None:          # got preempted: mask slot
                        self._clear_slot(slot)
                        self._trace_preempt(r)
                self.block_tables[req.slot] = 0
                self.block_tables[req.slot, :len(req.pages)] = req.pages

    def _poison_mask(self) -> np.ndarray:
        """Poll the ``decode.nonfinite`` fault site: a (max_slots,) bool
        mask of slots whose logits this step will NaN-poison (all-False
        keeps the jitted step's logits bitwise identical — zero parity
        cost on the fault-free path)."""
        poison = np.zeros((self.max_slots,), bool)
        spec = faults.poke("decode.nonfinite")
        if spec is not None:
            if spec.arg < 0:
                poison[:] = True
            else:
                poison[spec.arg % self.max_slots] = True
        return poison

    def _decode_dispatch(self):
        """Launch the jitted decode step for every RUNNING slot and return
        the in-flight record (None when nothing is running).  The host
        inputs are snapshotted into an alternating staging buffer, so the
        mirrors are free to mutate for the NEXT step while this one is on
        device; nothing here blocks on the result."""
        running = [r for r in self.sched.running.values()
                   if r.state is RequestState.RUNNING]
        if not running:
            return None
        with self._span("decode", batch=len(running)):
            buf = self._staging[self.n_decode_steps % 2]
            for name, host in buf.items():
                np.copyto(host, getattr(self, name))
            args = (self.params, jnp.asarray(buf["block_tables"]),
                    jnp.asarray(buf["lengths"]), jnp.asarray(buf["next_tok"]),
                    jnp.asarray(buf["temps"]), jnp.asarray(buf["topks"]),
                    jnp.asarray(buf["topps"]))
            prev_keys = self.keys    # NOT donated: reusable for the re-run
            toks, finite, pools, keys = self._decode(
                args[0], self.pools, *args[1:], prev_keys,
                jnp.asarray(self._poison_mask()))
            self.n_decode_steps += 1
            self.pools, self.keys = pools, keys
            return {"running": running, "args": args, "prev_keys": prev_keys,
                    "toks": toks, "finite": finite}

    def _decode_consume(self, inflight):
        """Block on a dispatched step's results and apply them — the only
        device sync in the loop.  Sync mode runs this right after the
        dispatch; async mode runs it at the top of the NEXT step, so the
        host's scheduling work for step N overlaps the device executing
        step N-1.  Either way the consume happens before any other
        mutation of that step, so the engine-state update order (and thus
        every sampled token) is identical across modes."""
        running, args = inflight["running"], inflight["args"]
        prev_keys = inflight["prev_keys"]
        toks, finite = inflight["toks"], inflight["finite"]
        with self._span("decode.consume", batch=len(running)):
            finite = np.asarray(finite)
            bad = [r for r in running if not finite[r.slot]]
            if bad and self.numerics_config.guard:
                # one-shot re-run of the whole step under the XLA-fallback
                # numerics scope.  Safe to replay against the post-step
                # pools (self.pools — nothing else has touched them since
                # the dispatch): the step only writes the current
                # position's K/V, which the re-run overwrites before
                # reading.  prev_keys keeps every fault-free slot's
                # sampling stream from advancing twice.
                self._stats["guard_trips"] += 1
                self._stats["fallback_reruns"] += 1
                tr = _current_tracer()
                if tr is not None:
                    tr.instant("fallback-rerun", cat="engine",
                               slots=[r.slot for r in bad])
                with numerics.use(self._fallback_numerics):
                    toks, finite, pools, keys = self._decode(
                        args[0], self.pools, *args[1:], prev_keys,
                        jnp.asarray(self._poison_mask()))
                finite = np.asarray(finite)
                self.pools, self.keys = pools, keys
            toks = np.asarray(toks)
            for req in running:
                if not finite[req.slot]:
                    # the fallback tripped too (or the guard is off): fail
                    # THIS request; its batch neighbours are unharmed
                    self._stats["numerics_errors"] += 1
                    self._finish(req, FinishReason.ERROR)
                    continue
                self.lengths[req.slot] += 1  # its input token is now cached
                req.key = self.keys[req.slot]
                self._accept_token(req, int(toks[req.slot]))

    # ------------------------------------------------------------- drive

    def _expire_deadlines(self):
        """Time out requests (running or queued) whose deadline tick has
        passed.  Runs at the top of every step, so a timed-out request
        never consumes another prefill or decode."""
        for req in list(self.sched.running.values()):
            if req.deadline is not None and self.clock > req.deadline:
                self._stats["timeouts"] += 1
                self._finish(req, FinishReason.TIMEOUT)
        for req in [r for r in
                    list(self.sched.waiting) + list(self.sched.parked)
                    if r.deadline is not None and self.clock > r.deadline]:
            self._stats["timeouts"] += 1
            req.finish_reason = FinishReason.TIMEOUT.value
            self.sched.drop(req)
            self._trace_request_end(req)

    def step(self):
        """One engine iteration: consume any in-flight async decode,
        tick the deadline clock, expire deadlines, admit + prefill,
        advance one prefill chunk, then dispatch one decode step for
        whatever is in flight — under the construction-time numerics and
        mesh scopes.  Sync mode (default) consumes the dispatch inline;
        async mode leaves it in flight until the next step."""
        with self._scopes(), self._span("engine.step") as sp:
            if self._inflight is not None:
                inflight, self._inflight = self._inflight, None
                self._decode_consume(inflight)
            self.clock += 1
            spec = faults.poke("decode.slow")
            if spec is not None:         # injected slowdown: burn ticks
                self.clock += max(1, spec.arg)
            self._expire_deadlines()
            self._admit_and_prefill()
            self._prefill_chunk_step()
            self._ensure_pages()
            inflight = self._decode_dispatch()
            if inflight is not None:
                if self.async_sched:
                    self._inflight = inflight
                else:
                    self._decode_consume(inflight)
            # annotated at exit: the span args dict is live until then
            sp["clock"] = self.clock
            sp["occupancy"] = len(self.sched.running)
            sp["waiting"] = len(self.sched.waiting)

    def run(self, prompts=None, params=None) -> dict[int, RequestResult]:
        """Convenience driver: optionally enqueue ``prompts`` (with one
        :class:`SamplingParams` each, or one shared), run to drain, and
        return :meth:`results` for everything enqueued since
        construction."""
        if prompts is not None:
            if params is None:
                params = [None] * len(prompts)
            elif isinstance(params, SamplingParams):
                params = [params] * len(prompts)
            for prompt, sp in zip(prompts, params):
                self.add_request(prompt, sp)
        while self.sched.has_work or self._inflight is not None:
            self.step()
        return self.results()

    def results(self) -> dict[int, RequestResult]:
        """``{rid: RequestResult}`` — generated tokens (list-compatible)
        plus ``finish_reason`` — for every request seen so far."""
        return {rid: RequestResult(req.out, req.finish_reason)
                for rid, req in self._requests.items()}

    def stats(self) -> dict:
        """Resilience and throughput counters: engine counters (guard
        trips, fallback re-runs, rejections, overloads, timeouts, length
        caps, prefill faults, numerics errors), scheduler counters
        (preemptions, parks), and the kernel circuit breaker's global
        totals.  All zero on a healthy fault-free run — the serving bench
        snapshot records them so CI gates on exactly that."""
        from repro.kernels import guard
        return {**self._stats,
                "clock": self.clock,
                "prefills": self.n_prefills,
                "prefill_chunks": self.n_prefill_chunks,
                "decode_steps": self.n_decode_steps,
                "preemptions": self.sched.n_preemptions,
                "parks": self.sched.n_parks,
                "breaker": guard.counters()}

    # ------------------------------------------------------------ defrag

    def defragment(self):
        """Compact live pages to the low end of the pool: permutes the
        device page arrays and re-indexes every running request's block
        table, prefix-cache node, and in-flight chunked prefill.  Safe
        between steps; output-invariant (tests assert)."""
        if self._inflight is not None:       # async: land the step first
            inflight, self._inflight = self._inflight, None
            with self._scopes():
                self._decode_consume(inflight)
        mapping = self.pool.defrag()
        perm = inverse_permutation(mapping, self.pool.num_pages)
        self.pools = permute_pages(self.pools, perm)
        if self.prefix is not None:
            self.prefix.remap(mapping)
        for req in self.sched.running.values():
            req.pages = [mapping[p] for p in req.pages]
            if req.state is RequestState.RUNNING:
                # PREFILLING slots keep zeroed (masked) block tables
                self.block_tables[req.slot] = 0
                self.block_tables[req.slot, :len(req.pages)] = req.pages


def _decode_and_sample(params, pools, block_tables, lengths, toks, temps,
                       topks, topps, keys, poison, *, model, cfg):
    """The jitted engine step: paged model decode + vectorized sampling +
    per-slot key advance, one dispatch for the whole slot array.

    Returns ``(tokens, finite, new_pools, new_keys)`` where ``finite`` is
    the per-slot isfinite guard bit — False means this slot's logits
    contain a non-finite value and its sampled token must not be trusted
    (the engine re-runs the step under the XLA-fallback scope).
    ``poison`` is the ``decode.nonfinite`` fault mask: poisoned slots get
    their logits NaN'd *after* the model forward, so an all-False mask is
    bitwise identical to the unpoisoned computation.
    """
    logits, new_pools = model.decode_step_paged(params, pools, block_tables,
                                                lengths, toks)
    logits = logits[:, :cfg.vocab_size].astype(jnp.float32)
    logits = jnp.where(poison[:, None], jnp.nan, logits)
    finite = jnp.all(jnp.isfinite(logits), axis=-1)   # (B,) guard bit
    # split convention must match the prefill draw (`key, sub = split(key)`:
    # carry row 0, sample with row 1) — otherwise a preemption's re-prefill
    # would resume a request's stream on the wrong side of the split
    split = jax.vmap(jax.random.split)(keys)          # (B, 2, 2)
    out = sampling.sample(logits, temps, topks, topps, split[:, 1])
    return out, finite, new_pools, split[:, 0]
