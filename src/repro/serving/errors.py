"""Structured serving errors and the finish-reason taxonomy.

Production serving needs to distinguish "your request was bad"
(:class:`RequestRejected`), "the server is full, retry later"
(:class:`EngineOverloaded`), and "something inside broke"
(:class:`ServingError` subclasses) — and every request that *does* run
must come back with a machine-readable statement of why it stopped
(:class:`FinishReason`).  Before this module the engine expressed all of
that as bare ``assert``/``ValueError``/``RuntimeError`` and silent
``RequestState.FINISHED`` flips, which is exactly the grab-bag a caller
cannot build retry/backpressure logic on (and the ``assert``\\ s vanish
under ``python -O``).

Exceptions (request never produces tokens):

* :class:`RequestRejected` — the request itself can never be served
  (``max_tokens < 1``, prompt beyond the per-slot page cap).  Subclasses
  ``ValueError``: rejection is an input-validation failure.
* :class:`EngineOverloaded` — the bounded waiting queue is full
  (``Engine(max_waiting=...)``); the backpressure signal.  Retryable.
* :class:`SchedulerInvariantError` / :class:`PagePoolError` — internal
  invariant violations (double free, finishing a non-resident request).
  These indicate a bug, not a bad request, and are never swallowed.

Finish reasons (request ran; ``Engine.run()`` returns them on each
:class:`RequestResult`):

=============  =========================================================
``stop``       hit one of its ``SamplingParams.stop_tokens``
``length``     generated ``max_tokens`` tokens
``length_cap`` hit the engine's per-slot page cap (server max context)
``timeout``    exceeded its per-request deadline (engine clock ticks)
``error``      numerics error: non-finite logits that the one-shot
               XLA-fallback re-run could not repair, or an unrecoverable
               prefill failure
=============  =========================================================

``rejected`` / ``overloaded`` complete the taxonomy for transport layers
that log exception outcomes in the same field as finish reasons; the
engine itself raises for those instead of returning a result.
"""
from __future__ import annotations

from enum import Enum

__all__ = ["FinishReason", "ServingError", "RequestRejected",
           "EngineOverloaded", "SchedulerInvariantError", "PagePoolError",
           "RequestResult"]


class FinishReason(str, Enum):
    """Why a request stopped producing tokens.  ``str``-valued so
    ``result.finish_reason == "stop"`` reads naturally at call sites."""
    STOP = "stop"
    LENGTH = "length"
    LENGTH_CAP = "length_cap"
    TIMEOUT = "timeout"
    ERROR = "error"
    # exception outcomes, for transports that log one unified field:
    REJECTED = "rejected"
    OVERLOADED = "overloaded"

    def __str__(self) -> str:          # str(reason) == "stop", not the repr
        return self.value


class ServingError(RuntimeError):
    """Base of the serving-layer error taxonomy."""


class RequestRejected(ServingError, ValueError):
    """The request can never be served as posed (invalid ``max_tokens``,
    prompt beyond the per-slot page cap).  Not retryable as-is."""


class EngineOverloaded(ServingError):
    """The bounded waiting queue is full — backpressure; retry later."""


class SchedulerInvariantError(ServingError):
    """A scheduler bookkeeping invariant was violated (engine bug)."""


class PagePoolError(ServingError):
    """A page-pool bookkeeping invariant was violated (double free,
    out-of-range page)."""


class RequestResult(list):
    """Generated tokens plus the finish reason.

    A ``list`` subclass so every existing call site — ``out[rid][:8]``,
    ``out[rid] == ref``, ``np.asarray(out[rid])`` — keeps working while
    new callers read ``out[rid].finish_reason``.
    """

    def __init__(self, tokens=(), finish_reason=None):
        super().__init__(int(t) for t in tokens)
        if isinstance(finish_reason, FinishReason):
            finish_reason = finish_reason.value
        self.finish_reason: str | None = finish_reason

    @property
    def tokens(self) -> list[int]:
        return list(self)

    def __repr__(self) -> str:
        return (f"RequestResult({list(self)!r}, "
                f"finish_reason={self.finish_reason!r})")
