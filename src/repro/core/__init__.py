"""repro.core — the paper's contribution: error-corrected low-precision GEMM
(Ootomo & Yokota 2022) as a composable JAX precision policy."""
from .policy import (POLICIES, PrecisionPolicy, get_policy, pdot, policy_bmm,
                     policy_mm)
from .split import MANTISSA_BITS, reconstruct, split

__all__ = [
    "POLICIES", "PrecisionPolicy", "get_policy", "pdot", "policy_bmm",
    "policy_mm", "MANTISSA_BITS", "split", "reconstruct",
]
