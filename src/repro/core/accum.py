"""Tensor-Core accumulator rounding simulators (paper Fig. 5 / Eq. 11).

The paper isolates the cause of Markidis-method error with two software
matrix-multiply-accumulate models: products in full precision, a 25-bit
accumulator (f32 + >=2 guard bits, per Fasi et al.), and the post-addition
rounding performed with RN (``mma_rn``) or RZ (``mma_rz``, what real Tensor
Cores do).  ``mma_rn`` reproduces SGEMM accuracy under Markidis' split while
``mma_rz`` reproduces Markidis' degraded accuracy — the smoking gun that moved
the paper to accumulate *outside* the matrix unit.

Implemented in numpy float64 with explicit mantissa re-quantization after
every accumulate; the k-loop is a host loop (analysis tool, small sizes).
"""
from __future__ import annotations

import numpy as np

ACC_BITS = 25  # f32 mantissa (24 incl. implicit) + guard bit, per the paper


def _round_to_bits(x: np.ndarray, p: int, mode: str) -> np.ndarray:
    """Requantize f64 mantissas to ``p`` bits with RN (ties-even) or RZ."""
    m, e = np.frexp(x)          # x = m * 2**e, |m| in [0.5, 1)
    s = m * (2.0 ** p)
    if mode == "rn":
        t = np.rint(s)          # ties-to-even
    elif mode == "rz":
        t = np.trunc(s)
    else:
        raise ValueError(mode)
    return np.ldexp(t, e - p)


def mma_sim(a_lp: np.ndarray, b_lp: np.ndarray, c: np.ndarray,
            mode: str, acc_bits: int = ACC_BITS) -> np.ndarray:
    """D <- A_lp x B_lp + C with per-element-accumulate rounding (Eq. 11).

    ``a_lp``/``b_lp`` are already low-precision-valued (any float dtype);
    products are exact (f64), the accumulator is requantized to ``acc_bits``
    after *every* element addition, starting from the addition of C —
    matching the paper's description of the TC pipeline.
    """
    a = np.asarray(a_lp, dtype=np.float64)
    b = np.asarray(b_lp, dtype=np.float64)
    acc = _round_to_bits(np.asarray(c, dtype=np.float64), acc_bits, mode)
    for k in range(a.shape[-1]):
        prod = a[..., :, k, None] * b[..., None, k, :]
        acc = _round_to_bits(acc + prod, acc_bits, mode)
    return acc


def markidis_gemm_sim(a32: np.ndarray, b32: np.ndarray, mode: str,
                      chain: bool = True) -> np.ndarray:
    """Markidis' 4-term corrected GEMM on the simulated accumulator.

    ``chain=True`` chains all four mma calls through one accumulator
    (paper Code 2 — rounding mode applies between terms too); this is the
    configuration of Fig. 5.
    """
    a_hi = a32.astype(np.float16)
    da = (a32 - a_hi.astype(np.float32)).astype(np.float16)
    b_hi = b32.astype(np.float16)
    db = (b32 - b_hi.astype(np.float32)).astype(np.float16)
    c = np.zeros(a32.shape[:-1] + (b32.shape[-1],), dtype=np.float64)
    terms = [(da, db), (da, b_hi), (a_hi, db), (a_hi, b_hi)]
    if not chain:
        return sum(mma_sim(x, y, np.zeros_like(c), mode) for x, y in terms)
    for x, y in terms:
        c = mma_sim(x, y, c, mode)
    return c
