"""Input-matrix generators for the paper's accuracy experiments.

``exp_rand`` implements Eq. (25); ``randtlr`` / ``spatial`` / ``cauchy``
reproduce the STARS-H exponent patterns of Figs. 12-13 (tile-low-rank random,
exponential spatial-statistics kernel, Cauchy matrix).
"""
from __future__ import annotations

import numpy as np


def urand(shape, lo=-1.0, hi=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


def exp_rand(shape, a: int, b: int, seed=0):
    """Eq. (25): exponent ~ U[a, b], mantissa ~ U[1, 2), random sign."""
    rng = np.random.default_rng(seed)
    e = rng.integers(a, b + 1, size=shape)
    m = rng.uniform(1.0, 2.0, size=shape)
    s = rng.integers(0, 2, size=shape) * 2 - 1
    return (s * np.exp2(e.astype(np.float64)) * m).astype(np.float32)


def randtlr(n: int, rank: int = 8, tile: int = 64, decay: float = 0.5, seed=0):
    """Random synthetic tile-low-rank matrix (STARS-H ``randtlr``)."""
    rng = np.random.default_rng(seed)
    nt = (n + tile - 1) // tile
    out = np.zeros((nt * tile, nt * tile), dtype=np.float64)
    for i in range(nt):
        for j in range(nt):
            u = rng.standard_normal((tile, rank))
            v = rng.standard_normal((rank, tile))
            mag = decay ** abs(i - j)
            out[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile] = mag * (u @ v) / rank
    return out[:n, :n].astype(np.float32)


def spatial(n: int, corr_len: float = 0.1, seed=0):
    """Exponential covariance kernel over random 2-D points (STARS-H ``spatial``)."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0.0, 1.0, size=(n, 2))
    d = np.sqrt(((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1))
    return np.exp(-d / corr_len).astype(np.float32)


def cauchy(n: int, seed=0):
    """Cauchy matrix 1 / (x_i - y_j) with separated generators."""
    rng = np.random.default_rng(seed)
    x = np.sort(rng.uniform(0.0, 1.0, size=n))
    y = np.sort(rng.uniform(1.5, 2.5, size=n))
    return (1.0 / (x[:, None] - y[None, :])).astype(np.float32)


def relative_residual(c_test: np.ndarray, a32: np.ndarray, b32: np.ndarray) -> float:
    """Paper Eq. (7): ||C_f64 - C_test||_F / ||C_f64||_F."""
    ref = a32.astype(np.float64) @ b32.astype(np.float64)
    num = np.linalg.norm(ref - np.asarray(c_test, dtype=np.float64))
    return float(num / np.linalg.norm(ref))
