"""Precision policies: the paper's technique as a framework-wide matmul knob.

Every weight/activation contraction in the model zoo routes through
:func:`pdot` (einsum front-end) or :func:`policy_mm` / :func:`policy_bmm`
(canonical 2D / batched matmul cores).  A :class:`PrecisionPolicy` selects

  * ``fp32``          — plain f32 GEMM (cublas_simt baseline of the paper)
  * ``bf16``          — single-pass bf16 MXU GEMM (TC-without-correction baseline)
  * ``tcec_bf16x3``   — 2-way bf16 split, 3 passes  (halfhalf-analogue on TPU)
  * ``tcec_bf16x6``   — 3-way bf16 split, 6 passes  (FP32-matching; the headline)
  * ``tcec_bf16x9``   — 3-way bf16 split, full 9-product grid + compensated
                        (TwoSum) accumulation: f64-grade unevaluated sums
  * ``tcec_bf16x10``  — 4-way bf16 split, triangular 10-pass schedule
  * ``tcec_fp8e4m3x6 / tcec_fp8e4m3x10 / tcec_fp8e5m2x6`` — fp8-storage
                        splits (throughput end of the frontier)
  * ``fp16_markidis`` — 2-way fp16 split, 4 passes, no scaling   (Eq. (6))
  * ``fp16_halfhalf`` — 2-way fp16 split, 3 passes, 2**11 scaling (Eq. (19)-(24))

The keep schedules of the families are derived programmatically
(:func:`triangular_keep` / :func:`full_keep`), so ``tcec_bf16x{n}``
generalizes past the paper's hand-written x3/x6 lists.

The emulation follows the paper's corrected accumulation discipline: each kept
split-product ``a_i @ b_j`` is an independent low-precision-in / f32-out GEMM
(the MXU contract: exact products, f32 accumulation — no RZ recoupling), and
same-scale products are summed into *separate* f32 accumulators which a scaled
epilogue folds from the smallest scale upward (Code 3's frag_c / frag_dc).

Backward passes are defined via ``custom_vjp`` so that the gradient GEMMs
``dA = g @ B^T`` and ``dB = A^T @ g`` use the *same* policy — on TPU both
directions stay on the MXU instead of falling back to f32 dots through the
autodiff of the cast chain.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .split import MANTISSA_BITS, split


@dataclass(frozen=True)
class PrecisionPolicy:
    """A GEMM execution recipe (see module docstring)."""
    name: str
    dtype: str = "float32"          # storage dtype of the split terms
    n_splits: int = 1               # number of split terms per operand
    scale_bits: int = 0             # residual pre-cast scale shift (Eq. 18)
    keep: tuple = ()                # kept product terms (i, j); () = all/plain
    upcast_products: bool = False   # f32-upcast operands before each pass
                                    # (fp16 reproduction path: TCs multiply in
                                    # full precision; XLA-CPU fp16 dots do not)
    compensated: bool = False       # error-free (TwoSum) group accumulation +
                                    # fold — f64-grade unevaluated sums from
                                    # exact narrow products (Chen/Verschelde
                                    # multi-double analogue); XLA path only

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def passes(self) -> int:
        return max(1, len(self.keep))

    @property
    def groups(self) -> tuple[int, ...]:
        """Scale groups of the kept products (ascending i+j) — one f32
        accumulator each in both the kernel and the XLA expansion."""
        return tuple(sorted({i + j for (i, j) in self.keep}))

    def is_plain(self) -> bool:
        return self.n_splits == 1


def triangular_keep(n_splits: int) -> tuple:
    """The paper's term schedule generalized to ``n``-way splits: keep every
    split product whose scale group ``i + j`` fits under the diagonal
    (``i + j <= n - 1``) — the terms that can still influence the recovered
    f32 result.  n=2 gives the x3 schedule, n=3 the headline x6, n=4 x10
    (the triangular numbers)."""
    return tuple(sorted(((i, j) for i in range(n_splits)
                         for j in range(n_splits) if i + j <= n_splits - 1),
                        key=lambda ij: (ij[0] + ij[1], ij)))


def full_keep(n_splits: int) -> tuple:
    """The full n x n product grid — no dropped cross terms, so the only
    residual left is the split representation error itself (the multi-double
    regime of Chen & Verschelde): n=3 gives the 9-pass schedule."""
    return tuple(sorted(((i, j) for i in range(n_splits)
                         for j in range(n_splits)),
                        key=lambda ij: (ij[0] + ij[1], ij)))


def _tcec(name, dtype, n_splits, keep=None, upcast=False, compensated=False):
    mb = MANTISSA_BITS[jnp.dtype(dtype)] + 1  # incl. implicit bit
    keep = triangular_keep(n_splits) if keep is None else tuple(keep)
    return PrecisionPolicy(name=name, dtype=dtype, n_splits=n_splits,
                           scale_bits=mb, keep=keep,
                           upcast_products=upcast, compensated=compensated)


POLICIES: dict[str, PrecisionPolicy] = {
    "fp32": PrecisionPolicy(name="fp32"),
    "bf16": PrecisionPolicy(name="bf16", dtype="bfloat16"),
    # TPU-native production policies -------------------------------------
    "tcec_bf16x3": _tcec("tcec_bf16x3", "bfloat16", 2,
                         [(0, 0), (0, 1), (1, 0)]),
    "tcec_bf16x6": _tcec("tcec_bf16x6", "bfloat16", 3,
                         [(0, 0), (0, 1), (1, 0), (1, 1), (0, 2), (2, 0)]),
    # multi-term family (beyond-f32 accuracy; ROADMAP "up" direction) -----
    # x9: full 3x3 grid + compensated accumulation — the unevaluated sum
    # carries ~2^-48 of relative error (f64-grade, see docs/numerics.md);
    # even folded to a single f32 it beats x6 by the f32 accumulation noise.
    "tcec_bf16x9": _tcec("tcec_bf16x9", "bfloat16", 3, full_keep(3),
                         compensated=True),
    # x10: 4-way triangular schedule on the plain fused-kernel path —
    # exercises the parametric n-split kernel (4 scale groups).
    "tcec_bf16x10": _tcec("tcec_bf16x10", "bfloat16", 4),
    # fp8 storage family (ROADMAP "down" direction; SNIPPETS.md Snippet 3).
    # upcast_products: no fp8 dot support is assumed of the backend — the
    # already-rounded terms are upcast to f32 before each pass, exactly the
    # fp16 reproduction escape hatch.
    "tcec_fp8e4m3x6": _tcec("tcec_fp8e4m3x6", "float8_e4m3fn", 3,
                            upcast=True),
    "tcec_fp8e4m3x10": _tcec("tcec_fp8e4m3x10", "float8_e4m3fn", 4,
                             upcast=True),
    "tcec_fp8e5m2x6": _tcec("tcec_fp8e5m2x6", "float8_e5m2", 3,
                            upcast=True),
    # paper-faithful reproduction policies (fp16 Tensor-Core model) -------
    "fp16_markidis": PrecisionPolicy(
        name="fp16_markidis", dtype="float16", n_splits=2, scale_bits=0,
        keep=((0, 0), (0, 1), (1, 0), (1, 1)), upcast_products=True),
    "fp16_halfhalf": PrecisionPolicy(
        name="fp16_halfhalf", dtype="float16", n_splits=2, scale_bits=11,
        keep=((0, 0), (0, 1), (1, 0)), upcast_products=True),
}


def get_policy(p) -> PrecisionPolicy:
    """Resolve a policy name / instance / None (None = the active
    :class:`repro.numerics.NumericsConfig`'s policy)."""
    if isinstance(p, PrecisionPolicy):
        return p
    if p is None:
        from repro import numerics
        p = numerics.active().policy
    return POLICIES[p]


# ---------------------------------------------------------------------------
# Emulated TCEC GEMM (XLA path; the Pallas kernel in repro.kernels fuses the
# same math into one VMEM-tiled kernel for the shapes it supports).
# ---------------------------------------------------------------------------

def _cpu_upcast_dots(cfg=None) -> bool:
    """XLA-CPU's thunk runtime lacks bf16 x bf16 -> f32 DotThunks for some
    shapes (execution-time UNIMPLEMENTED). On CPU we upcast the already-
    rounded operands to f32 — bit-identical results (bf16 -> f32 is exact,
    products/accumulation stay f32 = the MXU contract). The dry-run sets
    ``keep_bf16_dots`` (env: REPRO_KEEP_BF16_DOTS) so compiled-artifact
    byte accounting keeps the true bf16 operand traffic of the TPU
    target."""
    from repro import numerics
    if (cfg or numerics.active()).keep_bf16_dots:
        return False
    return jax.default_backend() == "cpu"


def _pass_dot(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """One split-product GEMM: low-precision in, f32 out (MXU contract)."""
    if policy.upcast_products or _cpu_upcast_dots(cfg):
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.float32,
                               precision=jax.lax.Precision.DEFAULT)


def _tcec_dot(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """Term-expanded GEMM with per-scale-group f32 accumulators + epilogue."""
    if policy.compensated:
        return _compensated_dot(a, b, policy, dims)[0]
    sa = split(a, policy.jdtype, policy.n_splits, policy.scale_bits)
    sb = split(b, policy.jdtype, policy.n_splits, policy.scale_bits)
    groups: dict[int, jax.Array] = {}
    for (i, j) in policy.keep:
        t = _pass_dot(sa[i], sb[j], policy, dims, cfg)
        g = i + j
        groups[g] = t if g not in groups else groups[g] + t
    # epilogue: fold scale groups smallest-first (paper Code 3: += dc / 2048)
    out = None
    for g in sorted(groups, reverse=True):
        term = groups[g] * jnp.float32(2.0 ** (-g * policy.scale_bits))
        out = term if out is None else out + term
    return out


# --- compensated (error-free) accumulation: the f64-emulation end -----------
#
# For narrow split terms the pass products are *exact* in f32 (bf16 x bf16
# needs <= 16 significand bits), so the only inexact step left is summation.
# Knuth's TwoSum makes each addition error-free — the group accumulators and
# the scaled epilogue fold become unevaluated (head, tail) pairs whose sum
# carries ~K * 2^-48 of relative error: f64-grade accuracy from bf16 storage
# (Chen & Verschelde's multi-double Tensor-Core arithmetic, PAPERS.md).
# Scaling by 2^(-g*s) is a power of two and stays exact.  The price is that
# the K-reduction runs as a sequential scan instead of one MXU dot, so
# compensated policies are the accuracy extreme of the frontier, not the
# throughput one, and kernels/dispatch.py declines them (rule 1).


def _two_sum(s, x):
    """Error-free transform: s + x = t + e exactly, t = fl(s + x)."""
    t = s + x
    z = t - s
    e = (s - (t - z)) + (x - z)
    return t, e


def _compensated_dot(a, b, policy: PrecisionPolicy, dims):
    """Split-product GEMM with TwoSum-compensated accumulation.

    Returns ``(head, tail)`` — the f32 unevaluated sum of the result
    (``head`` is the correctly-rounded f32 GEMM up to O(2^-48) terms;
    ``head + tail`` evaluated in higher precision is the f64-grade value).

    Operands are canonicalized (transpose + collapse) onto ``(B, M, K) x
    (B, K, N)``; unlike the plain path this does reshape, which is
    acceptable because compensated policies never dispatch to the fused
    kernels or the sharded fast path — they are the accuracy anchor.
    """
    (ca, cb), (ba, bb) = dims
    am = [d for d in range(a.ndim) if d not in ca and d not in ba]
    bn = [d for d in range(b.ndim) if d not in cb and d not in bb]
    at = jnp.transpose(a.astype(jnp.float32), list(ba) + am + list(ca))
    bt = jnp.transpose(b.astype(jnp.float32), list(bb) + list(cb) + bn)
    nb, nm, nk = len(ba), len(am), len(ca)
    bsh, msh = at.shape[:nb], at.shape[nb:nb + nm]
    ksh, nsh = at.shape[nb + nm:], bt.shape[nb + nk:]
    import math
    B, M = max(1, math.prod(bsh)), max(1, math.prod(msh))
    K, N = max(1, math.prod(ksh)), max(1, math.prod(nsh))
    a3 = at.reshape(B, M, K)
    b3 = bt.reshape(B, K, N)
    sa = [t.astype(jnp.float32) for t in
          split(a3, policy.jdtype, policy.n_splits, policy.scale_bits)]
    sb = [t.astype(jnp.float32) for t in
          split(b3, policy.jdtype, policy.n_splits, policy.scale_bits)]
    by_group: dict[int, list] = {}
    for (i, j) in policy.keep:
        by_group.setdefault(i + j, []).append((i, j))
    heads, tails = {}, {}
    for g, pairs in sorted(by_group.items()):
        # scan the K axis; each step TwoSums this k's pass products into
        # the group's (head, tail) accumulator panel
        ak = jnp.stack([jnp.moveaxis(sa[i], -1, 0) for (i, _) in pairs])
        bk = jnp.stack([jnp.moveaxis(sb[j], 1, 0) for (_, j) in pairs])

        def body(carry, xs, npairs=len(pairs)):
            s, c = carry
            xa, xb = xs                       # (P, B, M), (P, B, N)
            for p in range(npairs):
                prod = xa[p][:, :, None] * xb[p][:, None, :]   # exact in f32
                s, e = _two_sum(s, prod)
                c = c + e
            return (s, c), None

        zero = jnp.zeros((B, M, N), jnp.float32)
        (s, c), _ = jax.lax.scan(body, (zero, zero),
                                 (jnp.moveaxis(ak, 1, 0),
                                  jnp.moveaxis(bk, 1, 0)))
        heads[g], tails[g] = s, c
    # compensated smallest-first epilogue fold (exact power-of-two scales)
    out_s = jnp.zeros((B, M, N), jnp.float32)
    out_c = jnp.zeros((B, M, N), jnp.float32)
    for g in sorted(by_group, reverse=True):
        inv = jnp.float32(2.0 ** (-g * policy.scale_bits))
        out_s, e = _two_sum(out_s, heads[g] * inv)
        out_c = out_c + e + tails[g] * inv
    head, tail = _two_sum(out_s, out_c)
    shape = tuple(bsh) + tuple(msh) + tuple(nsh)
    return head.reshape(shape), tail.reshape(shape)


def tcec_dot_unevaluated(a, b, policy=None):
    """(M, K) @ (K, N) under a compensated policy, returned as the f32
    unevaluated pair ``(head, tail)`` — evaluate ``head + tail`` in f64 to
    see the emulated-f64 accuracy (docs/numerics.md, conformance battery)."""
    pol = get_policy(policy)
    if not pol.compensated:
        raise ValueError(f"policy {pol.name!r} is not compensated; only "
                         "compensated policies produce an unevaluated pair")
    dims = (((1,), (0,)), ((), ()))
    return _compensated_dot(a, b, pol, dims)


def _plain_dot(a, b, policy: PrecisionPolicy, dims, cfg=None):
    if policy.name == "fp32":
        return jax.lax.dot_general(a.astype(jnp.float32), b.astype(jnp.float32),
                                   dims, precision=jax.lax.Precision.HIGHEST,
                                   preferred_element_type=jnp.float32)
    lp = policy.jdtype
    a = a.astype(lp)
    b = b.astype(lp)
    if _cpu_upcast_dots(cfg):  # values stay lp-rounded; products/accum f32
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.float32,
                               precision=jax.lax.Precision.DEFAULT)


def _maybe_pallas(a, b, policy: PrecisionPolicy, dims, cfg):
    """Fused-kernel dispatch (kernels/dispatch.py), None -> XLA fallback.

    Imported lazily: repro.kernels imports this module at load time, so the
    dependency must point kernels -> core only at module scope."""
    from repro.kernels import dispatch
    return dispatch.maybe_dispatch(a, b, policy, dims, cfg)


def _dot_impl(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """One policy GEMM under one config.

    ``cfg`` is the hashable :class:`repro.numerics.NumericsConfig` the
    decision is made under — captured from the active context at *trace
    time* when not threaded explicitly.  Because the active config's epoch
    is part of the jit cache key (see ``repro.numerics.use``), a context
    change deterministically re-runs this function under the new config
    instead of reusing a stale lowering.
    """
    from repro import numerics
    if cfg is None:
        cfg = numerics.active()
    if policy.is_plain():
        # plain policies never reach kernels/dispatch.py — record the
        # rule-1 decline here so explain() covers every contraction
        from repro.obs.explain import record as _explain
        _explain("matmul", policy.name,
                 (tuple(a.shape), tuple(b.shape)), "plain-policy")
        return _plain_dot(a, b, policy, dims, cfg)
    out = _maybe_pallas(a, b, policy, dims, cfg)
    if out is not None:
        return out
    return _tcec_dot(a, b, policy, dims, cfg)


# --- canonical core with policy-preserving backward ------------------------
#
# Operands are only TRANSPOSED into (batch..., m..., k...) x (batch..., k...,
# n...) layout — never reshaped — and contracted with a multi-dim
# dot_general. Avoiding reshapes keeps GSPMD sharding propagation exact
# (reshape merges of a sharded dim are where propagation gives up and
# replicates, which for attention scores costs 16x memory per device).


@functools.lru_cache(maxsize=None)
def _make_dg(policy_name: str, nbatch: int, nm: int, nk: int, nn: int):
    policy = get_policy(policy_name)
    bdims = tuple(range(nbatch))

    def dims_fwd():
        ak = tuple(range(nbatch + nm, nbatch + nm + nk))
        bk = tuple(range(nbatch, nbatch + nk))
        return ((ak, bk), (bdims, bdims))

    @jax.custom_vjp
    def dg(at, bt):
        return _dot_impl(at, bt, policy, dims_fwd())

    def fwd(at, bt):
        return dg(at, bt), (at, bt)

    def bwd(res, g):
        at, bt = res
        # g: (batch, m, n); da = g . bt over n -> (batch, m, k)
        gn = tuple(range(nbatch + nm, nbatch + nm + nn))
        btn = tuple(range(nbatch + nk, nbatch + nk + nn))
        da = _dot_impl(g, bt, policy, ((gn, btn), (bdims, bdims)))
        # db = at . g over m -> (batch, k, n)
        atm = tuple(range(nbatch, nbatch + nm))
        gm = tuple(range(nbatch, nbatch + nm))
        db = _dot_impl(at, g, policy, ((atm, gm), (bdims, bdims)))
        return da.astype(at.dtype), db.astype(bt.dtype)

    dg.defvjp(fwd, bwd)
    return dg


def _maybe_monitor(a, b, policy: PrecisionPolicy, site: str):
    """Numerics-health probe hook (repro.obs.numerics_health), gated on
    ``NumericsConfig.monitor`` (default off -> no graph change at all).

    Called at trace time from the contraction front-ends — *outside* the
    ``custom_vjp`` core, so only forward operands are probed (debug
    callbacks inside custom_vjp rules are off-limits) and the probe runs
    once per contraction, not again per backward GEMM.
    """
    if policy.is_plain():
        return
    from repro import numerics
    if not numerics.active().monitor:
        return
    from repro.obs import numerics_health
    numerics_health.observe(a, b, policy, site=site)


def policy_mm(a, b, policy=None):
    """(M, K) @ (K, N) -> (M, N) f32 under ``policy`` (None = the active
    config's policy; env default ``fp32``)."""
    pol = get_policy(policy)
    _maybe_monitor(a, b, pol, "mm")
    return _make_dg(pol.name, 0, 1, 1, 1)(a, b)


def policy_bmm(a, b, policy=None):
    """(B, M, K) @ (B, K, N) -> (B, M, N) f32 under ``policy`` (None = the
    active config's policy; env default ``fp32``)."""
    pol = get_policy(policy)
    _maybe_monitor(a, b, pol, "bmm")
    return _make_dg(pol.name, 1, 1, 1, 1)(a, b)


# ---------------------------------------------------------------------------
# Binary einsum front-end: transpose -> dot_general core -> restore layout.
# ---------------------------------------------------------------------------

class EinsumParseError(ValueError):
    """Malformed / unsupported ``pdot`` subscripts.

    A typed error (not an ``assert``): subscript validation is a runtime
    input check and must survive ``python -O`` — a stripped assert would
    let a malformed spec silently mis-contract."""


def _parse(subscripts: str):
    spec = subscripts.replace(" ", "")
    if spec.count("->") != 1:
        raise EinsumParseError(
            f"pdot subscripts need exactly one '->': {subscripts!r}")
    lhs, out = spec.split("->")
    if lhs.count(",") != 1:
        raise EinsumParseError(
            f"pdot is a binary einsum (exactly one ','): {subscripts!r}")
    a_sub, b_sub = lhs.split(",")
    for sub in (a_sub, b_sub, out):
        if len(set(sub)) != len(sub):
            raise EinsumParseError(
                f"repeated index in {sub!r} (diagonals/traces are not "
                f"supported): {subscripts!r}")
    a_set, b_set, o_set = set(a_sub), set(b_sub), set(out)
    batch = [c for c in a_sub if c in b_set and c in o_set]
    contract = [c for c in a_sub if c in b_set and c not in o_set]
    m_dims = [c for c in a_sub if c not in b_set]
    n_dims = [c for c in b_sub if c not in a_set]
    if set(out) != set(batch) | set(m_dims) | set(n_dims):
        raise EinsumParseError(
            f"output indices {out!r} must be exactly the batch + uncontracted "
            f"operand indices of {subscripts!r}")
    return a_sub, b_sub, out, batch, contract, m_dims, n_dims


def pdot(subscripts: str, a, b, policy=None):
    """Policy-routed binary einsum (the framework's single GEMM chokepoint).

    Supports any two-operand einsum with no repeated/diagonal indices — i.e.
    every contraction in the model zoo (qkv/out projections, MLPs, MoE expert
    GEMMs, attention QK^T / PV, MLA low-rank factors, SSD chunk matmuls).
    ``policy=None`` resolves through the active numerics config.
    """
    policy = get_policy(policy)
    a_sub, b_sub, out, batch, contract, m_dims, n_dims = _parse(subscripts)

    def ax(sub, order):
        return [sub.index(c) for c in order]

    at = jnp.transpose(a, ax(a_sub, batch + m_dims + contract))
    bt = jnp.transpose(b, ax(b_sub, batch + contract + n_dims))
    _maybe_monitor(at, bt, policy, "pdot")
    core = _make_dg(policy.name, len(batch), len(m_dims), len(contract),
                    len(n_dims))
    o = core(at, bt)                     # (batch..., m..., n...)
    cur = batch + m_dims + n_dims
    return jnp.transpose(o, ax("".join(cur), out))
