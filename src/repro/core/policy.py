"""Precision policies: the paper's technique as a framework-wide matmul knob.

Every weight/activation contraction in the model zoo routes through
:func:`pdot` (einsum front-end) or :func:`policy_mm` / :func:`policy_bmm`
(canonical 2D / batched matmul cores).  A :class:`PrecisionPolicy` selects

  * ``fp32``          — plain f32 GEMM (cublas_simt baseline of the paper)
  * ``bf16``          — single-pass bf16 MXU GEMM (TC-without-correction baseline)
  * ``tcec_bf16x3``   — 2-way bf16 split, 3 passes  (halfhalf-analogue on TPU)
  * ``tcec_bf16x6``   — 3-way bf16 split, 6 passes  (FP32-matching; the headline)
  * ``fp16_markidis`` — 2-way fp16 split, 4 passes, no scaling   (Eq. (6))
  * ``fp16_halfhalf`` — 2-way fp16 split, 3 passes, 2**11 scaling (Eq. (19)-(24))

The emulation follows the paper's corrected accumulation discipline: each kept
split-product ``a_i @ b_j`` is an independent low-precision-in / f32-out GEMM
(the MXU contract: exact products, f32 accumulation — no RZ recoupling), and
same-scale products are summed into *separate* f32 accumulators which a scaled
epilogue folds from the smallest scale upward (Code 3's frag_c / frag_dc).

Backward passes are defined via ``custom_vjp`` so that the gradient GEMMs
``dA = g @ B^T`` and ``dB = A^T @ g`` use the *same* policy — on TPU both
directions stay on the MXU instead of falling back to f32 dots through the
autodiff of the cast chain.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .split import MANTISSA_BITS, split


@dataclass(frozen=True)
class PrecisionPolicy:
    """A GEMM execution recipe (see module docstring)."""
    name: str
    dtype: str = "float32"          # storage dtype of the split terms
    n_splits: int = 1               # number of split terms per operand
    scale_bits: int = 0             # residual pre-cast scale shift (Eq. 18)
    keep: tuple = ()                # kept product terms (i, j); () = all/plain
    upcast_products: bool = False   # f32-upcast operands before each pass
                                    # (fp16 reproduction path: TCs multiply in
                                    # full precision; XLA-CPU fp16 dots do not)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def passes(self) -> int:
        return max(1, len(self.keep))

    @property
    def groups(self) -> tuple[int, ...]:
        """Scale groups of the kept products (ascending i+j) — one f32
        accumulator each in both the kernel and the XLA expansion."""
        return tuple(sorted({i + j for (i, j) in self.keep}))

    def is_plain(self) -> bool:
        return self.n_splits == 1


def _tcec(name, dtype, n_splits, keep, upcast=False):
    mb = MANTISSA_BITS[jnp.dtype(dtype)] + 1  # incl. implicit bit
    return PrecisionPolicy(name=name, dtype=dtype, n_splits=n_splits,
                           scale_bits=mb, keep=tuple(keep),
                           upcast_products=upcast)


POLICIES: dict[str, PrecisionPolicy] = {
    "fp32": PrecisionPolicy(name="fp32"),
    "bf16": PrecisionPolicy(name="bf16", dtype="bfloat16"),
    # TPU-native production policies -------------------------------------
    "tcec_bf16x3": _tcec("tcec_bf16x3", "bfloat16", 2,
                         [(0, 0), (0, 1), (1, 0)]),
    "tcec_bf16x6": _tcec("tcec_bf16x6", "bfloat16", 3,
                         [(0, 0), (0, 1), (1, 0), (1, 1), (0, 2), (2, 0)]),
    # paper-faithful reproduction policies (fp16 Tensor-Core model) -------
    "fp16_markidis": PrecisionPolicy(
        name="fp16_markidis", dtype="float16", n_splits=2, scale_bits=0,
        keep=((0, 0), (0, 1), (1, 0), (1, 1)), upcast_products=True),
    "fp16_halfhalf": PrecisionPolicy(
        name="fp16_halfhalf", dtype="float16", n_splits=2, scale_bits=11,
        keep=((0, 0), (0, 1), (1, 0)), upcast_products=True),
}


def get_policy(p) -> PrecisionPolicy:
    """Resolve a policy name / instance / None (None = the active
    :class:`repro.numerics.NumericsConfig`'s policy)."""
    if isinstance(p, PrecisionPolicy):
        return p
    if p is None:
        from repro import numerics
        p = numerics.active().policy
    return POLICIES[p]


# ---------------------------------------------------------------------------
# Emulated TCEC GEMM (XLA path; the Pallas kernel in repro.kernels fuses the
# same math into one VMEM-tiled kernel for the shapes it supports).
# ---------------------------------------------------------------------------

def _cpu_upcast_dots(cfg=None) -> bool:
    """XLA-CPU's thunk runtime lacks bf16 x bf16 -> f32 DotThunks for some
    shapes (execution-time UNIMPLEMENTED). On CPU we upcast the already-
    rounded operands to f32 — bit-identical results (bf16 -> f32 is exact,
    products/accumulation stay f32 = the MXU contract). The dry-run sets
    ``keep_bf16_dots`` (env: REPRO_KEEP_BF16_DOTS) so compiled-artifact
    byte accounting keeps the true bf16 operand traffic of the TPU
    target."""
    from repro import numerics
    if (cfg or numerics.active()).keep_bf16_dots:
        return False
    return jax.default_backend() == "cpu"


def _pass_dot(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """One split-product GEMM: low-precision in, f32 out (MXU contract)."""
    if policy.upcast_products or _cpu_upcast_dots(cfg):
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.float32,
                               precision=jax.lax.Precision.DEFAULT)


def _tcec_dot(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """Term-expanded GEMM with per-scale-group f32 accumulators + epilogue."""
    sa = split(a, policy.jdtype, policy.n_splits, policy.scale_bits)
    sb = split(b, policy.jdtype, policy.n_splits, policy.scale_bits)
    groups: dict[int, jax.Array] = {}
    for (i, j) in policy.keep:
        t = _pass_dot(sa[i], sb[j], policy, dims, cfg)
        g = i + j
        groups[g] = t if g not in groups else groups[g] + t
    # epilogue: fold scale groups smallest-first (paper Code 3: += dc / 2048)
    out = None
    for g in sorted(groups, reverse=True):
        term = groups[g] * jnp.float32(2.0 ** (-g * policy.scale_bits))
        out = term if out is None else out + term
    return out


def _plain_dot(a, b, policy: PrecisionPolicy, dims, cfg=None):
    if policy.name == "fp32":
        return jax.lax.dot_general(a.astype(jnp.float32), b.astype(jnp.float32),
                                   dims, precision=jax.lax.Precision.HIGHEST,
                                   preferred_element_type=jnp.float32)
    lp = policy.jdtype
    a = a.astype(lp)
    b = b.astype(lp)
    if _cpu_upcast_dots(cfg):  # values stay lp-rounded; products/accum f32
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.float32,
                               precision=jax.lax.Precision.DEFAULT)


def _maybe_pallas(a, b, policy: PrecisionPolicy, dims, cfg):
    """Fused-kernel dispatch (kernels/dispatch.py), None -> XLA fallback.

    Imported lazily: repro.kernels imports this module at load time, so the
    dependency must point kernels -> core only at module scope."""
    from repro.kernels import dispatch
    return dispatch.maybe_dispatch(a, b, policy, dims, cfg)


def _dot_impl(a, b, policy: PrecisionPolicy, dims, cfg=None):
    """One policy GEMM under one config.

    ``cfg`` is the hashable :class:`repro.numerics.NumericsConfig` the
    decision is made under — captured from the active context at *trace
    time* when not threaded explicitly.  Because the active config's epoch
    is part of the jit cache key (see ``repro.numerics.use``), a context
    change deterministically re-runs this function under the new config
    instead of reusing a stale lowering.
    """
    from repro import numerics
    if cfg is None:
        cfg = numerics.active()
    if policy.is_plain():
        # plain policies never reach kernels/dispatch.py — record the
        # rule-1 decline here so explain() covers every contraction
        from repro.obs.explain import record as _explain
        _explain("matmul", policy.name,
                 (tuple(a.shape), tuple(b.shape)), "plain-policy")
        return _plain_dot(a, b, policy, dims, cfg)
    out = _maybe_pallas(a, b, policy, dims, cfg)
    if out is not None:
        return out
    return _tcec_dot(a, b, policy, dims, cfg)


# --- canonical core with policy-preserving backward ------------------------
#
# Operands are only TRANSPOSED into (batch..., m..., k...) x (batch..., k...,
# n...) layout — never reshaped — and contracted with a multi-dim
# dot_general. Avoiding reshapes keeps GSPMD sharding propagation exact
# (reshape merges of a sharded dim are where propagation gives up and
# replicates, which for attention scores costs 16x memory per device).


@functools.lru_cache(maxsize=None)
def _make_dg(policy_name: str, nbatch: int, nm: int, nk: int, nn: int):
    policy = get_policy(policy_name)
    bdims = tuple(range(nbatch))

    def dims_fwd():
        ak = tuple(range(nbatch + nm, nbatch + nm + nk))
        bk = tuple(range(nbatch, nbatch + nk))
        return ((ak, bk), (bdims, bdims))

    @jax.custom_vjp
    def dg(at, bt):
        return _dot_impl(at, bt, policy, dims_fwd())

    def fwd(at, bt):
        return dg(at, bt), (at, bt)

    def bwd(res, g):
        at, bt = res
        # g: (batch, m, n); da = g . bt over n -> (batch, m, k)
        gn = tuple(range(nbatch + nm, nbatch + nm + nn))
        btn = tuple(range(nbatch + nk, nbatch + nk + nn))
        da = _dot_impl(g, bt, policy, ((gn, btn), (bdims, bdims)))
        # db = at . g over m -> (batch, k, n)
        atm = tuple(range(nbatch, nbatch + nm))
        gm = tuple(range(nbatch, nbatch + nm))
        db = _dot_impl(at, g, policy, ((atm, gm), (bdims, bdims)))
        return da.astype(at.dtype), db.astype(bt.dtype)

    dg.defvjp(fwd, bwd)
    return dg


def _maybe_monitor(a, b, policy: PrecisionPolicy, site: str):
    """Numerics-health probe hook (repro.obs.numerics_health), gated on
    ``NumericsConfig.monitor`` (default off -> no graph change at all).

    Called at trace time from the contraction front-ends — *outside* the
    ``custom_vjp`` core, so only forward operands are probed (debug
    callbacks inside custom_vjp rules are off-limits) and the probe runs
    once per contraction, not again per backward GEMM.
    """
    if policy.is_plain():
        return
    from repro import numerics
    if not numerics.active().monitor:
        return
    from repro.obs import numerics_health
    numerics_health.observe(a, b, policy, site=site)


def policy_mm(a, b, policy=None):
    """(M, K) @ (K, N) -> (M, N) f32 under ``policy`` (None = the active
    config's policy; env default ``fp32``)."""
    pol = get_policy(policy)
    _maybe_monitor(a, b, pol, "mm")
    return _make_dg(pol.name, 0, 1, 1, 1)(a, b)


def policy_bmm(a, b, policy=None):
    """(B, M, K) @ (B, K, N) -> (B, M, N) f32 under ``policy`` (None = the
    active config's policy; env default ``fp32``)."""
    pol = get_policy(policy)
    _maybe_monitor(a, b, pol, "bmm")
    return _make_dg(pol.name, 1, 1, 1, 1)(a, b)


# ---------------------------------------------------------------------------
# Binary einsum front-end: transpose -> dot_general core -> restore layout.
# ---------------------------------------------------------------------------

def _parse(subscripts: str):
    lhs, out = subscripts.replace(" ", "").split("->")
    a_sub, b_sub = lhs.split(",")
    a_set, b_set, o_set = set(a_sub), set(b_sub), set(out)
    batch = [c for c in a_sub if c in b_set and c in o_set]
    contract = [c for c in a_sub if c in b_set and c not in o_set]
    m_dims = [c for c in a_sub if c not in b_set]
    n_dims = [c for c in b_sub if c not in a_set]
    assert set(out) == set(batch) | set(m_dims) | set(n_dims), subscripts
    return a_sub, b_sub, out, batch, contract, m_dims, n_dims


def pdot(subscripts: str, a, b, policy=None):
    """Policy-routed binary einsum (the framework's single GEMM chokepoint).

    Supports any two-operand einsum with no repeated/diagonal indices — i.e.
    every contraction in the model zoo (qkv/out projections, MLPs, MoE expert
    GEMMs, attention QK^T / PV, MLA low-rank factors, SSD chunk matmuls).
    ``policy=None`` resolves through the active numerics config.
    """
    policy = get_policy(policy)
    a_sub, b_sub, out, batch, contract, m_dims, n_dims = _parse(subscripts)

    def ax(sub, order):
        return [sub.index(c) for c in order]

    at = jnp.transpose(a, ax(a_sub, batch + m_dims + contract))
    bt = jnp.transpose(b, ax(b_sub, batch + contract + n_dims))
    _maybe_monitor(at, bt, policy, "pdot")
    core = _make_dg(policy.name, len(batch), len(m_dims), len(contract),
                    len(n_dims))
    o = core(at, bt)                     # (batch..., m..., n...)
    cur = batch + m_dims + n_dims
    return jnp.transpose(o, ax("".join(cur), out))
