"""Precision splitting — the paper's Eqs. (2)-(5) / (19)-(22), generalized.

An FP32 value ``v`` is decomposed into ``n`` low-precision terms

    v  ~=  a_0  +  a_1 * 2**-s  +  a_2 * 2**-2s  + ...

where each ``a_i`` is stored in a narrow dtype (bf16 on TPU, fp16 for the
paper-faithful reproduction) and ``s`` is the *scale shift* applied to each
residual before the narrowing cast (the paper's ``x 2**11`` of Eq. (18); we use
``s = mantissa bits`` of the target dtype so the residual's leading bits land in
the representable range, eliminating the underflow / gradual-underflow band the
paper analyzes in Eqs. (13)-(17)).

All casts use round-to-nearest-even (RN), the CUDA default the paper assumes;
an RZ variant is provided for reproducing the paper's Table 2 analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Mantissa bits (explicit, excluding the implicit leading 1) per storage dtype.
MANTISSA_BITS = {
    jnp.bfloat16.dtype: 7,
    jnp.float16.dtype: 10,
    jnp.float32.dtype: 23,
    jnp.dtype(jnp.float8_e4m3fn): 3,
    jnp.dtype(jnp.float8_e5m2): 2,
}


def _cast_rz(x: jax.Array, dtype) -> jax.Array:
    """Round-toward-zero cast of f32 -> {bf16, f16} (for Table-2 style analysis).

    bf16 is the upper 16 bits of f32, so RZ is a plain mask. f16 RZ is emulated
    by clearing the 13 low mantissa bits *after* aligning to the f16 quantum —
    we do it via frexp/ldexp which is exact for normal numbers (the RZ variant
    is an analysis tool; production splits use RN casts).
    """
    dtype = jnp.dtype(dtype)
    if dtype == jnp.bfloat16.dtype:
        bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
        return jax.lax.bitcast_convert_type(
            (bits & jnp.uint32(0xFFFF0000)).astype(jnp.uint32), jnp.float32
        ).astype(jnp.bfloat16)
    if dtype == jnp.float16.dtype:
        m, e = jnp.frexp(x.astype(jnp.float32))
        p = 11  # implicit + 10 explicit
        t = jnp.trunc(m * (2.0**p))
        return jnp.ldexp(t, e - p).astype(jnp.float16)
    raise ValueError(f"unsupported RZ cast target {dtype}")


def split(x: jax.Array, dtype, n_splits: int, scale_bits: int,
          rounding: str = "rn") -> list[jax.Array]:
    """Split f32 ``x`` into ``n_splits`` terms of ``dtype``.

    Returns ``[a_0, ..., a_{n-1}]`` with ``x ~= sum_i f32(a_i) * 2**(-i*scale_bits)``.
    ``scale_bits`` is applied to each residual before the cast (exponent-only,
    exact — it never touches the mantissa), reproducing the paper's Eq. (18).
    """
    x = x.astype(jnp.float32)
    dtype = jnp.dtype(dtype)
    cast = (lambda v: v.astype(dtype)) if rounding == "rn" else (
        lambda v: _cast_rz(v, dtype))
    scale = jnp.float32(2.0 ** scale_bits)
    out = []
    r = x
    for i in range(n_splits):
        a = cast(r)
        out.append(a)
        if i + 1 < n_splits:
            r = (r - a.astype(jnp.float32)) * scale
    return out


def reconstruct(parts: list[jax.Array], scale_bits: int) -> jax.Array:
    """Inverse of :func:`split` (up to representation error) in f32."""
    acc = jnp.zeros_like(parts[-1], dtype=jnp.float32)
    # smallest terms first for a numerically faithful epilogue (paper's Code 3
    # adds frag_dc/2048 into frag_c — we fold scale groups from the tail).
    for i, a in reversed(list(enumerate(parts))):
        acc = acc + a.astype(jnp.float32) * jnp.float32(2.0 ** (-i * scale_bits))
    return acc
