"""Paper theory, computed exactly: mantissa-length expectation (Tables 1-2)
and underflow probabilities (Eqs. 13-17), generalized to any split dtype.

The mantissa analysis enumerates *all* 2^23 FP32 mantissas (vectorized
integer arithmetic — no sampling error) and simulates the two-term split
``v ~= v_lp + dv_lp`` at a given low-precision width and rounding mode,
reporting the expected number of kept mantissa bits.  The paper's numbers
(RN: 22.75, RZ: 22.5 of 23 explicit bits for FP16 splits) fall out exactly.

The underflow analysis evaluates the closed forms P_u(e_v) / P_{u+gu}(e_v)
for arbitrary (mantissa length, exponent bias) so it covers both the paper's
FP16 Tensor Cores and this framework's bf16 MXU targets.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

F32_MANT = 23  # explicit bits


@dataclass(frozen=True)
class LPFormat:
    name: str
    mant: int   # explicit mantissa bits
    bias: int   # exponent bias

FP16 = LPFormat("fp16", 10, 15)
BF16 = LPFormat("bf16", 7, 127)
TF32 = LPFormat("tf32", 10, 127)
FP8E4M3 = LPFormat("fp8_e4m3", 3, 7)     # OCP e4m3fn: finite-only, max 448
FP8E5M2 = LPFormat("fp8_e5m2", 2, 15)

#: max unbiased exponent per format (e4m3fn spends the top code on 448, not
#: inf, hence 8; the rest follow IEEE ``bias`` symmetry)
MAX_UNBIASED_EXP = {"fp16": 15, "bf16": 127, "tf32": 127,
                    "fp8_e4m3": 8, "fp8_e5m2": 15}

#: jnp/np dtype-name -> analysis format, for policy-driven lookups
FORMATS_BY_DTYPE = {"float16": FP16, "bfloat16": BF16,
                    "float8_e4m3fn": FP8E4M3, "float8_e5m2": FP8E5M2}


def _round_int(v: np.ndarray, q: int, mode: str) -> np.ndarray:
    """Round integers ``v`` to multiples of ``q`` (q = power of two)."""
    if mode == "rz":
        return np.sign(v) * (np.abs(v) // q) * q
    # RN ties-to-even on the quotient
    quot = np.abs(v) / q
    t = np.rint(quot)  # ties-to-even for half-integers
    return np.sign(v) * t.astype(np.int64) * q


def split_kept_bits(lp_mant: int = 10, mode: str = "rn") -> np.ndarray:
    """Bits of FP32 mantissa lost by a 2-term split, for every mantissa.

    Models the mantissa of v as the 24-bit integer ``M = 2^23 + m`` (implicit
    bit set).  v_lp keeps the top ``lp_mant+1`` bits (quantum q0 = 2^(23-lp_mant-1+1)
    ... computed from M's width), the residual is requantized to an
    (lp_mant+1)-bit window at its own leading bit — floating-point, so the
    quantum depends on the residual's magnitude.  Returns, per mantissa value,
    the number of bits needed to store the final error (0 = exact).
    """
    width = lp_mant + 1                       # incl. implicit bit
    M = (np.arange(2 ** F32_MANT, dtype=np.int64) + (1 << F32_MANT))
    q0 = 1 << (F32_MANT + 1 - width)          # hi-part quantum
    hi = _round_int(M, q0, mode)
    r = M - hi
    # residual quantum: keep ``width`` bits at the residual's own leading bit
    absr = np.abs(r)
    lead = np.zeros_like(absr)
    nz = absr > 0
    lead[nz] = np.floor(np.log2(absr[nz])).astype(np.int64)
    q1 = np.where(lead + 1 > width, 1 << np.maximum(lead + 1 - width, 0), 1)
    lo = _round_int(r, q1, mode)
    err = np.abs(M - (hi + lo))
    bits = np.zeros_like(err)
    nz = err > 0
    bits[nz] = np.floor(np.log2(err[nz])).astype(np.int64) + 1
    return bits


def expected_mantissa_length(lp_mant: int = 10, mode: str = "rn") -> float:
    """E[kept mantissa length] of the 2-term split (Table 1/2 bottom line)."""
    bits_lost = split_kept_bits(lp_mant, mode)
    return F32_MANT - float(bits_lost.mean())


def p_l0(n: int, lp_mant: int = 10) -> float:
    """Paper Eq. (14): distribution of l0 = run of zeros below the hi part."""
    lmax = F32_MANT - lp_mant
    if n < 0 or n > lmax:
        return 0.0
    if n == lmax:
        return 0.5 ** lmax
    return 0.5 ** (n + 1)


def p_underflow_gradual(e_v: int, fmt: LPFormat = FP16,
                        scale_bits: int = 0) -> float:
    """Eq. (15): P[underflow or gradual underflow] in the residual cast.

    ``e_v`` is the unbiased exponent of v_f32; ``scale_bits`` models the
    paper's Eq. (18) pre-cast scaling (adds to the residual exponent).
    """
    lmax = F32_MANT - fmt.mant
    lo = (e_v + scale_bits) - fmt.mant + fmt.bias - 2
    return sum(p_l0(l, fmt.mant) for l in range(max(lo + 1, 0), lmax + 1))


def p_underflow(e_v: int, fmt: LPFormat = FP16, scale_bits: int = 0) -> float:
    """Eq. (17): P[full underflow] in the residual cast."""
    lmax = F32_MANT - fmt.mant
    lo = (e_v + scale_bits) + fmt.bias - 2
    return sum(p_l0(l, fmt.mant) for l in range(max(lo + 1, 0), lmax + 1))


def p_underflow_term(e_v: int, fmt: LPFormat = FP16, scale_bits: int = 0,
                     term: int = 1) -> float:
    """Eq. (15) generalized to the ``i``-th term of an n-way split.

    Term ``i`` stores the ``i``-th residual, whose leading bit sits
    ``i * (mant+1)`` below ``e_v`` before the ``i * scale_bits`` pre-cast
    scaling — so its effective exponent is ``e_v + i*(scale_bits-(mant+1))``
    entering the same one-step closed form.  With the production convention
    ``scale_bits = mant + 1`` every term sees the same underflow
    probability as the first (the scaling walks the residual back up to
    ``e_v`` each stage)."""
    if term < 1:
        return 0.0
    drift = (term - 1) * (scale_bits - (fmt.mant + 1))
    return p_underflow_gradual(e_v + drift, fmt, scale_bits)


def safe_exponent_range(fmt: LPFormat, scale_bits: int,
                        max_e: int | None = None) -> tuple[int, int]:
    """Band of unbiased f32 operand exponents where the split is exact-safe:
    the closed-form P_{u+gu} (Eq. 15) is 0.0 at the low end and the scaled
    residual cannot overflow ``max_e`` at the high end.

    May be *empty* (lo > hi): fp8_e4m3's 4-bit exponent cannot hold a
    zero-underflow band at any operand exponent — every fp8_e4m3 split
    carries the gradual-underflow floor that
    :func:`split_residual_bound` accounts for."""
    if max_e is None:
        max_e = MAX_UNBIASED_EXP[fmt.name]
    lo = next((e for e in range(-148, 129)
               if p_underflow_gradual(e, fmt, scale_bits) == 0.0), 129)
    hi = max_e + fmt.mant + 1 - scale_bits
    return lo, hi


def representable_range(fmt: LPFormat, max_e: int | None = None
                        ) -> tuple[int, int]:
    """Unbiased operand exponents the *first* split term can store at all
    (normal range, no overflow) — the practical band for fp8 policies whose
    strict zero-underflow band is empty."""
    if max_e is None:
        max_e = MAX_UNBIASED_EXP[fmt.name]
    return -(fmt.bias - 1), max_e - 1


# ------------------------------------------------------------------ bounds
#
# Closed-form relative-error budget of an n-term split GEMM, the contract
# the policy-conformance battery holds every POLICIES entry to.  All terms
# are relative to sum_k |a_ik||b_kj| (elementwise), then converted to the
# Eq. (7) Frobenius relative residual by the sqrt(K) concentration factor
# for the zero-mean generators of core/matgen (a factor-4 safety margin is
# applied on top; bounds are upper bounds, not estimates).


def split_residual_bound(fmt: LPFormat, n_splits: int, scale_bits: int,
                         e_lo: int = 0, e_hi: int = 0) -> float:
    """Per-operand relative representation error after an n-way RN split.

    Two regimes, whichever floor is higher:
      * capture width — each RN cast halves the residual ``mant+1`` times:
        ``2^(-n (mant+1))``;
      * subnormal quantum — when the band ``[e_lo, e_hi]`` dips below the
        format's zero-underflow range, stage ``n-1``'s residual is captured
        at the subnormal quantum ``2^(1 - bias - mant)`` (descaled by its
        ``(n-1) * scale_bits`` shift), relative to the smallest operand.
    """
    w = fmt.mant + 1
    cap = 2.0 ** (-n_splits * w)
    lo_safe, _ = safe_exponent_range(fmt, scale_bits)
    if e_lo >= lo_safe:
        return cap
    quantum = 2.0 ** (1 - fmt.bias - fmt.mant
                      - (n_splits - 1) * scale_bits - e_lo)
    return max(cap, quantum)


def dropped_product_bound(keep, n_splits: int, fmt: LPFormat) -> float:
    """Relative weight of the split products the schedule drops: term ``i``
    carries at most ``2^(-i (mant+1))`` of the operand, so product ``(i, j)``
    contributes at most ``2^(-(i+j)(mant+1))`` of ``|a||b|``."""
    w = fmt.mant + 1
    kept = set(keep)
    return sum(2.0 ** (-(i + j) * w)
               for i in range(n_splits) for j in range(n_splits)
               if (i, j) not in kept)


def policy_error_bound(policy, k_depth: int,
                       e_lo: int = 0, e_hi: int = 0) -> float:
    """Upper bound on the Eq. (7) relative residual of one policy GEMM over
    a K-deep contraction with operand exponents inside ``[e_lo, e_hi]``.

    ``policy`` is a PrecisionPolicy (or name).  Budget = representation
    (both operands) + dropped cross products + accumulation:
      * plain f32: f32 dot rounding only;
      * plain lp: one RN cast per operand;
      * split, plain accumulation: per-scale-group f32 accumulators add
        ``~sqrt(K) 2^-24`` (RMS over the Frobenius norm; worst case would
        be K u, but Eq. (7) aggregates thousands of outputs);
      * split, compensated: TwoSum leaves ``K^2 2^-48`` plus the final
        f32 rounding of the folded head.
    """
    import math
    from . import policy as P
    pol = P.get_policy(policy) if not hasattr(policy, "keep") else policy
    u32 = 2.0 ** -24
    acc_plain = 4.0 * math.sqrt(max(k_depth, 1)) * u32
    if pol.is_plain():
        if pol.name == "fp32" or pol.jdtype == np.float32:
            return acc_plain + 4.0 * u32
        fmt = FORMATS_BY_DTYPE[pol.dtype]
        return 4.0 * 2.0 * 2.0 ** -(fmt.mant + 1) + acc_plain
    fmt = FORMATS_BY_DTYPE[pol.dtype]
    rep = split_residual_bound(fmt, pol.n_splits, pol.scale_bits, e_lo, e_hi)
    drop = dropped_product_bound(pol.keep, pol.n_splits, fmt)
    if pol.compensated:
        acc = max(k_depth, 1) ** 2 * 2.0 ** -48 + 2.0 * u32
    else:
        acc = acc_plain
    return 4.0 * (2.0 * rep + drop) + acc


def measure_underflow(e_v: int, fmt: LPFormat = FP16, scale_bits: int = 0,
                      n: int = 200_000, seed: int = 0) -> tuple[float, float]:
    """Monte-Carlo counterpart of Eqs. (15)/(17) using real IEEE casts.

    Draws v with fixed exponent ``e_v`` and uniform mantissa, performs the
    paper's split with RZ in the hi cast (the assumption under which the
    closed forms are derived), and counts residuals that land at zero
    (underflow) or in the subnormal band (gradual underflow).
    Returns (P_u, P_{u+gu}).
    """
    import ml_dtypes  # ships with jax
    rng = np.random.default_rng(seed)
    m = rng.integers(0, 2 ** F32_MANT, size=n, dtype=np.int64)
    v = ((1 << F32_MANT) + m).astype(np.float64) * 2.0 ** (e_v - F32_MANT)
    v = v.astype(np.float32)
    np_lp = {"fp16": np.float16, "bf16": ml_dtypes.bfloat16,
             "fp8_e4m3": ml_dtypes.float8_e4m3fn,
             "fp8_e5m2": ml_dtypes.float8_e5m2}[fmt.name]
    # hi part with RZ (theory assumption): truncate to fmt.mant+1 bits
    width = fmt.mant + 1
    mm, ee = np.frexp(v.astype(np.float64))
    hi = np.ldexp(np.trunc(mm * 2.0 ** width), ee - width).astype(np.float32)
    resid = ((v.astype(np.float64) - hi) * 2.0 ** scale_bits).astype(np.float32)
    dlp = resid.astype(np_lp)
    exact_zero = resid == 0
    tiny = 2.0 ** (-(fmt.bias - 1))          # smallest normal in lp
    u = (dlp.astype(np.float32) == 0) & ~exact_zero
    gu = (np.abs(dlp.astype(np.float32)) < tiny) & ~exact_zero
    return float(u.mean()), float(gu.mean())


def representable_relative_error(values: np.ndarray, policy_name: str) -> np.ndarray:
    """Fig. 9: relative representation error of each policy over a value grid."""
    from . import policy as P
    import jax.numpy as jnp
    from .split import split as jsplit, reconstruct
    v = np.asarray(values, dtype=np.float32)
    pol = P.get_policy(policy_name) if policy_name in P.POLICIES else None
    if policy_name == "fp32":
        rec = v.astype(np.float32)
    elif policy_name in ("fp16", "bf16"):
        import ml_dtypes
        dt = {"fp16": np.float16, "bf16": ml_dtypes.bfloat16}[policy_name]
        rec = v.astype(dt).astype(np.float64)
    else:
        parts = jsplit(jnp.asarray(v), pol.jdtype, pol.n_splits, pol.scale_bits)
        rec = np.asarray(reconstruct(parts, pol.scale_bits), dtype=np.float64)
    ref = v.astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        rel = np.abs(rec - ref) / np.abs(ref)
    return np.where(ref == 0, 0.0, rel)
