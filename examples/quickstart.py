"""Quickstart: the paper's technique in five lines, then inside a model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

import repro
from repro.core.matgen import relative_residual, urand
from repro import tcec_matmul

# --- 1. An FP32-accurate GEMM computed with 6 bf16 MXU passes ------------
a, b = urand((512, 1024), seed=0), urand((1024, 256), seed=1)
for pol in ["fp32", "bf16", "tcec_bf16x3", "tcec_bf16x6"]:
    c = repro.matmul(jnp.asarray(a), jnp.asarray(b), policy=pol)
    print(f"{pol:13s} relative residual = "
          f"{relative_residual(np.asarray(c), a, b):.2e}")

# --- 2. Same math as an explicit fused Pallas kernel ---------------------
c_kernel = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy="tcec_bf16x6")
print("pallas kernel residual =",
      f"{relative_residual(np.asarray(c_kernel), a, b):.2e}")

# --- 3. The same policy knob drives a whole model -------------------------
from repro.configs import get_smoke_config
from repro.models import get_model

for pol in ["fp32", "tcec_bf16x6", "bf16"]:
    cfg = get_smoke_config("qwen3-0.6b").replace(policy=pol)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32))),
    }
    loss, _ = model.loss_fn(params, batch)
    print(f"qwen3-smoke loss under {pol:13s} = {float(loss):.6f}")
