"""Tour of the `repro.numerics` public API: the context-scoped recipe.

One config object (`repro.numerics.NumericsConfig`) carries the whole
recipe — precision policy, kernel dispatch, autotuning — with one
precedence rule: call-site kwarg > innermost `use(...)` context > env
defaults (the `REPRO_*` registry).  Contexts are trace-correct: entering
one re-lowers previously-jitted shapes instead of reusing a stale
dispatch decision.

Run:  PYTHONPATH=src python examples/numerics_tour.py
"""
import numpy as np
import jax
import jax.numpy as jnp

import repro
from repro import numerics

rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((256, 512)).astype(np.float32))
b = jnp.asarray(rng.standard_normal((512, 256)).astype(np.float32))

# --- 1. Policy sweep under nested contexts -------------------------------
# The innermost context wins; the call-site kwarg beats both.
f64 = np.asarray(a, np.float64) @ np.asarray(b, np.float64)


def residual(c):
    return float(np.linalg.norm(np.asarray(c, np.float64) - f64)
                 / np.linalg.norm(f64))


print(f"{'selection':34s} {'policy':13s} rel.residual")
print(f"{'env default':34s} {numerics.active().policy:13s} "
      f"{residual(repro.matmul(a, b)):.2e}")
with numerics.use(policy="bf16"):
    print(f"{'use(policy=bf16)':34s} {numerics.active().policy:13s} "
          f"{residual(repro.matmul(a, b)):.2e}")
    with numerics.use(policy="tcec_bf16x6"):      # nested context wins
        print(f"{'  nested use(policy=tcec_bf16x6)':34s} "
              f"{numerics.active().policy:13s} "
              f"{residual(repro.matmul(a, b)):.2e}")
        c = repro.matmul(a, b, policy="tcec_bf16x3")   # kwarg beats both
        print(f"{'    call-site policy=tcec_bf16x3':34s} "
              f"{'tcec_bf16x3':13s} {residual(c):.2e}")

# --- 2. One parity check: corrected bf16 GEMM vs plain fp32 --------------
# The paper's claim, in two lines: the 6-pass bf16 split matches the f32
# GEMM to f32-level accuracy while a single bf16 pass visibly does not.
c_f32 = repro.matmul(a, b, policy="fp32")
c_tcec = repro.matmul(a, b, policy="tcec_bf16x6")
c_bf16 = repro.matmul(a, b, policy="bf16")
err_tcec = float(jnp.max(jnp.abs(c_tcec - c_f32)))
err_bf16 = float(jnp.max(jnp.abs(c_bf16 - c_f32)))
print(f"\nmax |tcec_bf16x6 - fp32| = {err_tcec:.2e}   "
      f"max |bf16 - fp32| = {err_bf16:.2e}")
assert err_tcec < 1e-3 < err_bf16, "corrected GEMM should track fp32"

# --- 3. Trace-correct contexts (the fixed footgun) -----------------------
# A context entered AFTER a shape was jitted still changes its dispatch:
# the active config's epoch is part of the jit cache key.
trace_log = []


@jax.jit
def f(a, b):
    trace_log.append(numerics.active().enabled)    # runs at trace time only
    return repro.matmul(a, b, policy="tcec_bf16x6")


f(a, b)                                            # traced under defaults
with numerics.use(enabled=False):                  # same shape, new recipe
    f(a, b)                                        # -> fresh lowering
assert trace_log == [True, False], trace_log
print(f"\ntrace log across contexts: {trace_log} "
      "(one fresh lowering per distinct config)")

# --- 4. The env registry is the single source of truth ------------------
print(f"\n{len(numerics.ENV_VARS)} registered REPRO_* variables:")
for row in numerics.describe_env():
    print(f"  {row['name']:26s} ({row['type']}, default {row['default']!r})")
