"""Tour of sharded TCEC dispatch on a fake multi-device CPU mesh.

Forces 4 CPU devices (before jax import), then walks the sharded stack:

  1. mesh setup + plan inspection — which dims each mesh axis shards;
  2. sharded matmul parity: N-sharded (bit-exact) and K-sharded (local
     fold first, one f32 psum after — f32-level agreement, the documented
     reduction-order guarantee of docs/parallel.md);
  3. sharded attention parity: head-sharded, bit-exact vs the unsharded
     fused kernel, with the kernel-call counter proving the route;
  4. a sharded train step on the same mesh (params land sharded).

Run:  PYTHONPATH=src python examples/shard_tour.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax                                                       # noqa: E402
import jax.numpy as jnp                                          # noqa: E402
import numpy as np                                               # noqa: E402

import repro                                                     # noqa: E402
from repro import numerics                                       # noqa: E402
from repro.parallel import ctx                                   # noqa: E402

# ----------------------------------------------------- 1. mesh + plans
mesh = jax.make_mesh((2, 2), ("data", "model"))
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

plan = repro.shmap.matmul_plan((256, 256), (256, 256), mesh)
print(f"square GEMM plan: shard {plan.sharded_dim}, local (B,M,N,K) = "
      f"{plan.local}")
plan_k = repro.shmap.matmul_plan((4, 131, 256), (4, 256, 129), mesh)
print(f"odd-N GEMM plan:  shard {plan_k.sharded_dim}, "
      f"psum over {plan_k.psum_axes}")

# ------------------------------------------------- 2. matmul parity
rng = np.random.default_rng(0)
with numerics.use(force=True, interpret=True, min_dim=0,
                  block=(128, 128, 128)):
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    ref = repro.matmul(a, b, policy="tcec_bf16x6")
    with ctx.use_mesh(mesh):
        out = repro.matmul(a, b, policy="tcec_bf16x6")
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    print("N-sharded matmul: bit-identical to the unsharded kernel")

    ak = jnp.asarray(rng.standard_normal((4, 131, 256)), jnp.float32)
    bk = jnp.asarray(rng.standard_normal((4, 256, 129)), jnp.float32)
    refk = repro.matmul(ak, bk, policy="tcec_bf16x6")
    with ctx.use_mesh(mesh):
        outk = repro.matmul(ak, bk, policy="tcec_bf16x6")
    err = float(jnp.max(jnp.abs(outk - refk)))
    assert err < 1e-4, err
    print(f"K-sharded matmul: f32 psum after the local fold, "
          f"max |diff| = {err:.2e}")

    # ------------------------------------------- 3. attention parity
    q = jnp.asarray(rng.standard_normal((2, 256, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 4, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 4, 64)), jnp.float32)
    with numerics.use(attn_block=(128, 128)):
        refa = repro.attention(q, k, v, policy="tcec_bf16x6", window=37)
        n0 = repro.shmap.counters()["attention"]
        with ctx.use_mesh(mesh):
            outa = repro.attention(q, k, v, policy="tcec_bf16x6", window=37)
    assert repro.shmap.counters()["attention"] == n0 + 1
    assert np.array_equal(np.asarray(outa), np.asarray(refa))
    aplan = repro.shmap.attention_plan(q.shape, k.shape, mesh)
    print(f"{aplan.mode}-sharded attention: routed via shard_map "
          f"(counter {n0} -> {n0 + 1}), bit-identical")

# --------------------------------------------- 4. sharded train step
import tempfile                                                  # noqa: E402

from repro.configs import get_smoke_config                       # noqa: E402
from repro.data.pipeline import DataConfig                       # noqa: E402
from repro.optim import adamw                                    # noqa: E402
from repro.train.loop import TrainLoopConfig, train              # noqa: E402

cfg = get_smoke_config("qwen3-0.6b")
with tempfile.TemporaryDirectory() as d:
    state, hist = train(cfg, adamw.OptConfig(lr=1e-3),
                        DataConfig(seed=0, global_batch=4, seq_len=32),
                        TrainLoopConfig(total_steps=2, ckpt_every=100),
                        d, mesh=mesh, log=lambda m: None)
shardings = {str(leaf.sharding.spec) for leaf in
             jax.tree.leaves(state["params"])
             if not leaf.sharding.is_fully_replicated}
print(f"sharded train step: loss {hist[-1]['loss']:.4f}, "
      f"{len(shardings)} distinct param specs on the mesh")
print("shard tour complete")
