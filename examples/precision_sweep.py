"""Precision-policy ablation (the paper's Fig. 1, at model scale): train the
same small LM under fp32 / tcec_bf16x6 / tcec_bf16x3 / bf16 and compare loss
trajectories. tcec_bf16x6 tracks fp32 to ~1e-4 while bf16 visibly diverges —
the paper's accuracy claim, measured end-to-end through an optimizer.

Run:  PYTHONPATH=src python examples/precision_sweep.py [--steps 60]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, device_batch
from repro.launch.step import make_train_step
from repro.models import get_model
from repro.optim import adamw


def run_policy(policy: str, steps: int):
    cfg = get_smoke_config("qwen3-0.6b").replace(policy=policy)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=5, total_steps=steps)
    state = {"params": params, "opt": adamw.init_state(params, opt)}
    step = jax.jit(make_train_step(cfg, opt))
    data = DataConfig(seed=0, global_batch=8, seq_len=64)
    losses = []
    for i in range(steps):
        batch = device_batch(cfg, data, i)
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return np.asarray(losses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    ref = run_policy("fp32", args.steps)
    print(f"{'policy':13s} {'final loss':>10s} {'max |Δ| vs fp32':>16s}")
    print(f"{'fp32':13s} {ref[-1]:10.4f} {'—':>16s}")
    for pol in ["tcec_bf16x6", "tcec_bf16x3", "bf16"]:
        ls = run_policy(pol, args.steps)
        dev = float(np.max(np.abs(ls - ref)))
        print(f"{pol:13s} {ls[-1]:10.4f} {dev:16.6f}")


if __name__ == "__main__":
    main()
