"""End-to-end driver: train a ~100M-param qwen3-family LM for a few hundred
steps on CPU with the full production stack (TCEC precision policy, AdamW,
deterministic pipeline, checkpoint/restart).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults are sized for a laptop-class CPU run; pass --d-model 768
 --layers 12 for the full ~100M configuration on a beefier box)
"""
import argparse

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--policy", default="tcec_bf16x6")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_smoke_config("qwen3-0.6b").replace(
        n_layers=args.layers, d_model=args.d_model, vocab_size=args.vocab,
        n_heads=max(args.d_model // 64, 4),
        n_kv_heads=max(args.d_model // 128, 2),
        head_dim=64, d_ff=args.d_model * 3, policy=args.policy)
    n_params = (cfg.padded_vocab * cfg.d_model
                + cfg.n_layers * (cfg.d_model * (cfg.n_heads
                                                 + 2 * cfg.n_kv_heads)
                                  * cfg.head_dim
                                  + cfg.n_heads * cfg.head_dim * cfg.d_model
                                  + 3 * cfg.d_model * cfg.d_ff))
    print(f"config: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
          f"~{n_params/1e6:.1f}M params, policy={cfg.policy}")

    opt = adamw.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    data = DataConfig(seed=0, global_batch=args.batch, seq_len=args.seq)
    loop = TrainLoopConfig(total_steps=args.steps, ckpt_every=100)
    state, hist = train(cfg, opt, data, loop, args.ckpt_dir)
    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"loss: first-10 avg {first:.4f} -> last-10 avg {last:.4f} "
          f"({'LEARNED' if last < first else 'no progress?'})")


if __name__ == "__main__":
    main()
