"""Serving example: mixed-length prompts with per-request sampling params
through the continuous-batching engine (paged KV cache, slot recycling),
then the family-agnostic back-compat ``generate`` on an SSM arch (O(1)
state — no KV cache, so it takes the dense loop).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import get_model
from repro.serving import Engine, SamplingParams

# --- continuous batching: 5 requests of different lengths on 3 slots ----
cfg = get_smoke_config("qwen3-0.6b")
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

engine = Engine(cfg, params, max_slots=3, num_pages=64, page_size=8)
requests = [
    (rng.integers(0, cfg.vocab_size, 5),
     SamplingParams(max_tokens=12)),                       # greedy
    (rng.integers(0, cfg.vocab_size, 17),
     SamplingParams(temperature=0.8, top_k=40, max_tokens=10, seed=1)),
    (rng.integers(0, cfg.vocab_size, 9),
     SamplingParams(temperature=0.7, top_p=0.9, max_tokens=8, seed=2)),
    (rng.integers(0, cfg.vocab_size, 3),
     SamplingParams(max_tokens=6, stop_tokens=(13,))),     # early stop ok
    (rng.integers(0, cfg.vocab_size, 12),
     SamplingParams(temperature=1.0, top_k=8, top_p=0.95, max_tokens=9,
                    seed=4)),
]
t0 = time.time()
rids = [engine.add_request(p, sp) for p, sp in requests]
out = engine.run()
dt = time.time() - t0
toks = sum(len(v) for v in out.values())
print(f"engine: {len(requests)} mixed-length requests on "
      f"{engine.max_slots} slots -> {toks} tokens in {dt:.1f}s "
      f"({engine.n_prefills} prefills, {engine.n_decode_steps} decode steps, "
      f"incl. compile)")
for (prompt, sp), rid in zip(requests, rids):
    mode = "greedy" if sp.greedy else f"T={sp.temperature}"
    print(f"  req {rid}: prompt {len(prompt):2d} tok, {mode:8s} "
          f"[{out[rid].finish_reason}] -> {out[rid][:8]}")

# --- back-compat generate(): SSM family, dense-loop fallback ------------
cfg = get_smoke_config("mamba2-130m")
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
prompts = np.asarray(rng.integers(0, cfg.vocab_size, (4, 8)), np.int32)
t0 = time.time()
o = generate(cfg, params, prompts, gen_len=16)
dt = time.time() - t0
print(f"mamba2-130m    generated {o.shape}  {4*16/dt:6.1f} tok/s "
      f"(dense fallback, incl. compile)  sample: {np.asarray(o[0][:8])}")
