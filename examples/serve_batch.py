"""Batched serving example: prefill + greedy decode on the mamba2 smoke
config (SSM decode is O(1)-state — no KV cache growth), then the same on a
transformer to show the family-agnostic serving API.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import get_model

for arch in ["mamba2-130m", "qwen3-0.6b"]:
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8)), jnp.int32)
    t0 = time.time()
    out = generate(cfg, params, prompts, gen_len=16)
    dt = time.time() - t0
    print(f"{arch:14s} generated {out.shape}  {4*16/dt:6.1f} tok/s "
          f"(incl. compile)  sample: {np.asarray(out[0][:8])}")
