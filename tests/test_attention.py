"""Fused TCEC flash-attention kernel: parity vs the pdot composition.

The kernel runs under ``interpret=True`` on CPU.  The ``mha`` reference
(materialized scores, the model's own fallback) is the oracle:

  * **exact policy cases** — one 128-aligned K block covering the whole KV
    length, head dim in {64, 128}, no softcap: the kernel normalizes the
    probs tile before the split P·V product, reproducing mha's exact
    operation sequence, and must match **bit for bit**;
  * **tolerance cases** — multi-block online softmax, padded S/T/head
    dims, softcap (tanh contracts differently inside vs outside the kernel
    graph — same ULP-level effect as the fused gelu epilogue): f32-level
    agreement.

Also covers: dispatch eligibility + escape hatches (REPRO_DISABLE_PALLAS
must restore the pure-XLA path for every attention call site), the
attention autotuner namespace, the causal short-circuit in the XLA
``blocked_attention`` fallback, and the decode-path mask.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import numerics
from repro.core.policy import get_policy
from repro.kernels import dispatch, tuning
from repro.numerics import NumericsConfig
from repro.kernels.tcec_attention import (NEG_INF as KERNEL_NEG_INF,
                                          attn_vmem_bytes, tcec_attention)
from repro.kernels.tcec_matmul import VMEM_BUDGET
from repro.models import layers as L


class _Cfg:
    """Minimal stand-in for ModelConfig's attention-relevant fields."""
    def __init__(self, mix_policy="tcec_bf16x6", attn_softcap=None):
        self.mix_policy = mix_policy
        self.attn_softcap = attn_softcap
        self.policy = mix_policy


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _bits(x):
    return np.asarray(x, dtype=np.float32).view(np.uint32)


def _qkv(B, S, T, H, Hkv, hd, hdv, seed=0):
    q = _rand((B, S, H, hd), seed)
    k = _rand((B, T, Hkv, hd), seed + 1)
    v = _rand((B, T, Hkv, hdv), seed + 2)
    qp = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    kp = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    return q, k, v, qp, kp


def test_neg_inf_constants_match():
    """The kernel's mask bias must be the models' mask bias, exactly —
    it's part of the bit-parity contract."""
    assert KERNEL_NEG_INF == L.NEG_INF


# ------------------------------------------------------------ exact cases

@pytest.mark.parametrize("policy", ["tcec_bf16x3", "tcec_bf16x6"])
@pytest.mark.parametrize("rep,hd", [(1, 64), (2, 64), (4, 64)])
def test_kernel_bit_identical_to_mha_single_block(policy, rep, hd):
    """Acceptance: one K block covering the 128-aligned KV length, no
    softcap, 64-lane head dim -> the fused kernel is bit-identical to the
    pdot composition, across GQA ratios and both split policies.  (Other
    head dims shift XLA's reduction grouping by ULPs — those are the
    tolerance cases below.)"""
    Hkv = 2
    q, k, v, qp, kp = _qkv(2, 128, 128, Hkv * rep, Hkv, hd, hd, seed=3)
    ref = L.mha(q, k, v, _Cfg(policy), qp, kp, causal=True, window=0)
    out = tcec_attention(q, k, v, qp, kp, policy=policy, causal=True,
                         window=0, block=(128, 128), interpret=True)
    assert np.array_equal(_bits(out), _bits(ref)), (policy, rep, hd)


def test_kernel_bit_identical_padded_queries_and_bigger_kv_block():
    """S needs padding (200 -> 256) but T is covered by one 256-block:
    padded q rows are sliced off, real rows stay bit-exact."""
    q, k, v, qp, kp = _qkv(1, 200, 256, 8, 2, 64, 64, seed=4)
    ref = L.mha(q, k, v, _Cfg(), qp, kp, causal=True, window=0)
    out = tcec_attention(q, k, v, qp, kp, policy="tcec_bf16x6", causal=True,
                         window=0, block=(128, 256), interpret=True)
    assert np.array_equal(_bits(out), _bits(ref))


def test_kernel_bit_identical_non_causal():
    q, k, v, qp, kp = _qkv(1, 128, 128, 4, 2, 64, 64, seed=5)
    ref = L.mha(q, k, v, _Cfg(), qp, kp, causal=False, window=0)
    out = tcec_attention(q, k, v, qp, kp, policy="tcec_bf16x6", causal=False,
                         window=0, block=(128, 128), interpret=True)
    assert np.array_equal(_bits(out), _bits(ref))


# -------------------------------------------------------- tolerance cases

TOL = dict(rtol=2e-6, atol=2e-6)

CASES = [
    # (desc, B, S, T, H, Hkv, hd, hdv, block, causal, window, softcap)
    ("online-causal", 1, 256, 256, 4, 2, 64, 64, (128, 128), True, 0, None),
    ("online-window", 1, 256, 256, 2, 2, 64, 64, (128, 128), True, 100, None),
    ("softcap", 1, 128, 128, 4, 4, 128, 128, (128, 128), True, 0, 20.0),
    ("softcap-window-gqa4", 1, 256, 256, 8, 2, 64, 64, (128, 128), True, 37,
     50.0),
    ("odd-but-aligned", 1, 384, 384, 4, 2, 64, 64, (128, 128), True, 0, None),
    ("padded-odd-shapes", 1, 100, 200, 4, 2, 32, 32, (128, 128), True, 0,
     None),
    ("padded-head-dim", 1, 128, 128, 8, 8, 192, 128, (128, 128), True, 0,
     None),
    ("cross-shaped", 1, 128, 256, 4, 2, 64, 64, (128, 128), False, 0, None),
]


@pytest.mark.parametrize(
    "desc,B,S,T,H,Hkv,hd,hdv,block,causal,window,softcap",
    CASES, ids=[c[0] for c in CASES])
def test_kernel_matches_mha_within_tolerance(desc, B, S, T, H, Hkv, hd, hdv,
                                             block, causal, window, softcap):
    # stable per-case seed — str hash() varies with PYTHONHASHSEED
    seed = 100 + [c[0] for c in CASES].index(desc)
    q, k, v, qp, kp = _qkv(B, S, T, H, Hkv, hd, hdv, seed=seed)
    ref = L.mha(q, k, v, _Cfg(attn_softcap=softcap), qp, kp, causal=causal,
                window=window)
    out = tcec_attention(q, k, v, qp, kp, policy="tcec_bf16x6", causal=causal,
                         window=window, softcap=softcap, block=block,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_kernel_accepts_traced_window():
    """window may be a traced scalar (the scanned local/global layer path):
    it feeds the kernel as a runtime operand, not a static param."""
    q, k, v, qp, kp = _qkv(1, 256, 256, 2, 2, 64, 64, seed=9)

    @jax.jit
    def fused(w):
        return tcec_attention(q, k, v, qp, kp, policy="tcec_bf16x6",
                              causal=True, window=w, block=(128, 128),
                              interpret=True)

    ref = L.mha(q, k, v, _Cfg(), qp, kp, causal=True, window=77)
    np.testing.assert_allclose(np.asarray(fused(jnp.int32(77))),
                               np.asarray(ref), **TOL)


# --------------------------------------------------- dispatch + routing

def test_attention_dispatch_eligibility():
    q = jnp.ones((1, 128, 4, 64))
    k = jnp.ones((1, 128, 2, 64))
    v = jnp.ones((1, 128, 2, 64))
    kw = dict(force=True, interpret=True, min_dim=0, attn_block=(128, 128))
    with numerics.use(**kw):
        assert dispatch.attention(q, k, v, policy="tcec_bf16x6") is not None
        assert dispatch.attention(q, k, v, policy="fp32") is None
        assert dispatch.attention(q, k, v, policy="bf16") is None
        assert dispatch.attention(q, k, v, policy="fp16_halfhalf") is None
    with numerics.use(**{**kw, "min_dim": 256}):
        assert dispatch.attention(q, k, v, policy="tcec_bf16x6") is None
    # off-TPU without force: decline (the XLA fallback is the default path)
    assert jax.default_backend() != "tpu"
    assert dispatch.attention(q, k, v, policy="tcec_bf16x6") is None


def test_escape_hatches_cover_attention():
    q = jnp.ones((1, 128, 4, 64))
    k = jnp.ones((1, 128, 2, 64))
    v = jnp.ones((1, 128, 2, 64))
    # REPRO_DISABLE_PALLAS covers attention wholesale...
    with numerics.use(force=True, interpret=True, min_dim=0,
                      enabled=False):
        assert dispatch.attention(q, k, v, policy="tcec_bf16x6") is None
    # ...and the granular hatch covers only attention
    with numerics.use(force=True, interpret=True, min_dim=0,
                      flash_attention=False):
        assert dispatch.attention(q, k, v, policy="tcec_bf16x6") is None
    # the env spellings parse through the registry into the same fields
    assert not NumericsConfig.from_env({"REPRO_DISABLE_PALLAS": "1"}).enabled
    cfg = NumericsConfig.from_env({"REPRO_DISABLE_FLASH_ATTN": "1"})
    assert cfg.enabled and not cfg.flash_attention
    assert NumericsConfig.from_env(
        {"REPRO_DISABLE_FLASH_ATTN": "0"}).flash_attention


def test_attention_layer_routes_through_kernel():
    """models.layers.attention end to end: the fused path must agree with
    the pure-XLA path (same layer params, same inputs)."""

    class Cfg:
        d_model, n_heads, n_kv_heads, head_dim = 64, 4, 2, 64
        qkv_bias = False
        qk_norm = False
        attn_softcap = None
        rope_theta = 10_000.0
        norm_eps = 1e-6
        policy = "tcec_bf16x6"
        mix_policy = "tcec_bf16x6"

    p = L.attn_init(jax.random.PRNGKey(0), Cfg)
    x = _rand((2, 128, 64), 11)
    pos = jnp.broadcast_to(jnp.arange(128, dtype=jnp.int32)[None], (2, 128))
    with numerics.use(enabled=False):
        y_xla = L.attention(p, x, Cfg, pos, causal=True, window=0)
    with numerics.use(force=True, interpret=True, min_dim=0,
                           attn_block=(128, 128)):
        y_fused = L.attention(p, x, Cfg, pos, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_xla),
                               rtol=1e-5, atol=1e-5)


def test_fused_attention_is_differentiable_and_matches_fallback_grads():
    """Regression (review finding): the raw Pallas kernel has no VJP, so
    sdpa's fused route must carry the recompute custom_vjp — jax.grad
    through a dispatched attention call has to work (it's every training
    step on TPU) and agree with the pure-XLA gradients."""
    cfg = _Cfg()
    q, k, v, qp, kp = _qkv(1, 128, 128, 4, 2, 64, 64, seed=19)

    def loss(q, k, v):
        return jnp.sum(L.sdpa(q, k, v, cfg, qp, kp, causal=True,
                              window=0) ** 2)

    with numerics.use(force=True, interpret=True, min_dim=0,
                           attn_block=(128, 128)):
        gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    with numerics.use(enabled=False):
        rq, rk, rv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g, r in [(gq, rq), (gk, rk), (gv, rv)]:
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4)


def test_fused_attention_grad_with_traced_window():
    """The custom_vjp must also cope with a traced window operand (the
    scanned local/global layer path) — its cotangent is float0."""
    cfg = _Cfg()
    q, k, v, qp, kp = _qkv(1, 128, 128, 2, 2, 64, 64, seed=20)

    @jax.jit
    def g(q, w):
        return jax.grad(lambda q: jnp.sum(L.sdpa(
            q, k, v, cfg, qp, kp, causal=True, window=w) ** 2))(q)

    with numerics.use(force=True, interpret=True, min_dim=0,
                           attn_block=(128, 128)):
        gq = g(q, jnp.int32(40))
    with numerics.use(enabled=False):
        rq = jax.grad(lambda q: jnp.sum(L.sdpa(
            q, k, v, cfg, qp, kp, causal=True, window=40) ** 2))(q)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(rq),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------- autotuner namespace

def test_attention_autotune_namespace_roundtrip(tmp_path):
    calls = []

    def fake_measure(block):
        calls.append(block)
        return 1.0 + abs(block[1] - 256) / 1e3   # prefers bk=256

    cache = tuning.BlockCache(path=str(tmp_path / "tune.json"))
    blk, meta = tuning.autotune_attention(1, 2, 2, 512, 512, 128, 128,
                                          "tcec_bf16x6",
                                          measure=fake_measure, cache=cache)
    assert meta["source"] == "measured" and blk[1] == 256
    n = len(calls)
    blk2, meta2 = tuning.autotune_attention(1, 2, 2, 512, 512, 128, 128,
                                            "tcec_bf16x6",
                                            measure=fake_measure, cache=cache)
    assert blk2 == blk and meta2["source"] == "cache" and len(calls) == n
    # attention entries live in their own key namespace — never colliding
    # with a GEMM entry of the same shape numbers
    key = tuning.attn_cache_key(1, 2, 2, 512, 512, 128, 128,
                                "tcec_bf16x6", jax.default_backend())
    assert "/attn/" in key
    assert key != tuning.cache_key(1, 512, 512, 512, "tcec_bf16x6",
                                   jax.default_backend())
    # causal is part of the key: the kernel's block-level causal skip makes
    # causal and non-causal sweeps favor different blocks
    assert key != tuning.attn_cache_key(1, 2, 2, 512, 512, 128, 128,
                                        "tcec_bf16x6",
                                        jax.default_backend(), causal=False)


def test_attention_candidates_respect_vmem_and_alignment():
    pol = get_policy("tcec_bf16x6")
    for blk in tuning.attn_candidate_blocks(4096, 4096, 8, 128, 128,
                                            "tcec_bf16x6"):
        assert all(s % 128 == 0 for s in blk)
        assert attn_vmem_bytes(blk, 8, 128, 128, pol) <= VMEM_BUDGET
    assert tuning.attn_candidate_blocks(128, 128, 1, 64, 64,
                                        "tcec_bf16x6") == [(128, 128)]
    blk = tuning.attn_heuristic_block(4096, 4096, 4, 128, 128, "tcec_bf16x6")
    assert attn_vmem_bytes(blk, 4, 128, 128, pol) <= VMEM_BUDGET


def test_vmem_filter_judges_padded_head_dims():
    """Regression (review finding): the tuner filters candidates with the
    caller's unpadded head dims, but the kernel asserts the budget on the
    128-padded shapes — the filter must judge what actually runs, or a
    'feasible' block aborts inside jit for high-rep GQA configs."""
    pol = get_policy("tcec_bf16x6")
    # the padded working set is what counts...
    assert attn_vmem_bytes((128, 128), 16, 32, 32, pol) \
        == attn_vmem_bytes((128, 128), 16, 128, 128, pol)
    # ...so every candidate/heuristic block for extreme rep survives the
    # kernel's own assert
    for rep, hd in [(16, 32), (16, 64), (8, 192)]:
        for blk in tuning.attn_candidate_blocks(4096, 4096, rep, hd, hd,
                                                "tcec_bf16x6"):
            padded = 128 * ((hd + 127) // 128)
            assert attn_vmem_bytes(blk, rep, padded, padded,
                                   pol) <= VMEM_BUDGET, (rep, hd, blk)


def test_dispatch_declines_when_min_block_exceeds_vmem():
    """Regression (review finding): extreme-rep GQA (rep ~ 128) can't fit
    even a (128, 128) block in VMEM — eligibility must decline to the XLA
    path instead of letting the kernel's budget assert fire inside jit."""
    pol = get_policy("tcec_bf16x6")
    assert attn_vmem_bytes((128, 128), 128, 128, 128, pol) > VMEM_BUDGET
    q = jnp.ones((1, 128, 128, 128))   # H=128, Hkv=1 -> rep=128
    k = jnp.ones((1, 128, 1, 128))
    v = jnp.ones((1, 128, 1, 128))
    with numerics.use(force=True, interpret=True, min_dim=0):
        assert not dispatch.attention_eligible(q, k, v, policy="tcec_bf16x6")
        assert dispatch.attention(q, k, v, policy="tcec_bf16x6") is None


def test_dispatch_under_mesh_routes_or_declines():
    """Under an installed GSPMD mesh the fused path now runs through the
    ``shard_map`` wrapper (kernels/shmap.py).  It declines only when the
    knob is off (``use(shard_map=False)`` / ``REPRO_SHARD_MAP=0``) or the
    installed spec is unsupported — the pdot fallback keeps those calls."""
    from jax.sharding import Mesh
    from repro.kernels import shmap
    from repro.parallel import ctx
    q = jnp.ones((1, 128, 4, 64))
    k = jnp.ones((1, 128, 2, 64))
    v = jnp.ones((1, 128, 2, 64))
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("model",))
    with numerics.use(force=True, interpret=True, min_dim=0,
                           attn_block=(128, 128)):
        ref = dispatch.attention(q, k, v, policy="tcec_bf16x6")
        assert ref is not None
        with ctx.use_mesh(mesh):
            n0 = shmap.counters()["attention"]
            out = dispatch.attention(q, k, v, policy="tcec_bf16x6")
            assert out is not None                      # routed, not declined
            assert shmap.counters()["attention"] == n0 + 1   # via the wrapper
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
            # the knob restores the decline
            with numerics.use(shard_map=False):
                assert not dispatch.attention_eligible(
                    q, k, v, policy="tcec_bf16x6")
                assert dispatch.attention(q, k, v,
                                          policy="tcec_bf16x6") is None
        # unsupported spec (model axis divides neither Hkv nor S): decline
        class _FakeMesh:
            shape = {"model": 3}
            axis_names = ("model",)
        with ctx.use_mesh(_FakeMesh()):
            assert not dispatch.attention_eligible(q, k, v,
                                                   policy="tcec_bf16x6")


# ------------------------------------------- XLA fallback causal shortcut

def test_blocked_attention_causal_short_circuit_matches_mha():
    """The ki <= qi short-circuit must not change results: skipped chunks
    carried exactly zero probability mass."""
    cfg = _Cfg()
    q, k, v, qp, kp = _qkv(1, 256, 256, 4, 2, 32, 32, seed=13)
    ref = L.mha(q, k, v, cfg, qp, kp, causal=True, window=0)
    out = L.blocked_attention(q, k, v, cfg, qp, kp, causal=True, window=0,
                              q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_blocked_attention_short_circuit_handles_tied_positions():
    """Regression (review finding): the skip predicate is position-based,
    so duplicate positions straddling a chunk boundary (packed/padded
    sequences) still reach every chunk that carries unmasked d == 0
    entries."""
    cfg = _Cfg()
    q, k, v, _, _ = _qkv(1, 128, 128, 2, 2, 32, 32, seed=21)
    # nondecreasing with ties across the 32-wide chunk boundary
    pos = jnp.asarray(np.arange(128) // 2, jnp.int32)[None]
    ref = L.mha(q, k, v, cfg, pos, pos, causal=True, window=0)
    out = L.blocked_attention(q, k, v, cfg, pos, pos, causal=True, window=0,
                              q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_blocked_attention_short_circuit_is_differentiable():
    """Regression (review finding): the skip must use lax.cond — a
    dynamic-bound fori_loop has no reverse-mode derivative, and
    blocked_attention sits on the long-sequence *training* path."""
    cfg = _Cfg()
    q, k, v, qp, kp = _qkv(1, 128, 128, 2, 2, 32, 32, seed=22)

    def loss(q):
        return jnp.sum(L.blocked_attention(q, k, v, cfg, qp, kp, causal=True,
                                           window=0, q_chunk=32,
                                           k_chunk=32) ** 2)

    def loss_ref(q):
        return jnp.sum(L.mha(q, k, v, cfg, qp, kp, causal=True,
                             window=0) ** 2)

    g = jax.grad(loss)(q)
    g_ref = jax.grad(loss_ref)(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


def test_blocked_attention_full_scan_paths_unchanged():
    """window / non-causal / traced-window calls keep the full scan."""
    cfg = _Cfg()
    q, k, v, qp, kp = _qkv(1, 256, 256, 2, 2, 32, 32, seed=14)
    for causal, window in [(True, 100), (False, 0)]:
        ref = L.mha(q, k, v, cfg, qp, kp, causal=causal, window=window)
        out = L.blocked_attention(q, k, v, cfg, qp, kp, causal=causal,
                                  window=window, q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # traced window (the scanned local/global layer path) still traces
    out_t = jax.jit(lambda w: L.blocked_attention(
        q, k, v, cfg, qp, kp, causal=True, window=w,
        q_chunk=64, k_chunk=64))(jnp.int32(100))
    ref_t = L.mha(q, k, v, cfg, qp, kp, causal=True, window=100)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(ref_t),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- decode masking

def test_attention_decode_ignores_stale_cache_tail():
    """The decode mask is k_pos <= cache_index directly: garbage beyond the
    write point must not leak into the output (bitwise)."""

    class Cfg:
        d_model, n_heads, n_kv_heads, head_dim = 32, 2, 1, 16
        qkv_bias = False
        qk_norm = False
        attn_softcap = None
        rope_theta = 10_000.0
        norm_eps = 1e-6
        policy = "fp32"
        mix_policy = "fp32"

    p = L.attn_init(jax.random.PRNGKey(1), Cfg)
    x = _rand((1, 1, 32), 15)
    T, ci = 16, 4
    base = {"k": jnp.zeros((1, T, 1, 16), jnp.bfloat16),
            "v": jnp.zeros((1, T, 1, 16), jnp.bfloat16)}
    # non-finite garbage in K on purpose: an additive mask bias would leak
    # it through the scores (inf + NEG_INF = inf, NaN + anything = NaN) —
    # the mask must select.  V garbage stays finite: a zero probability
    # times finite V is exactly 0, but 0 * NaN would be NaN in any scheme.
    garbage = {
        "k": base["k"].at[:, ci + 1:].set(jnp.inf).at[:, ci + 2:].set(
            jnp.nan),
        "v": base["v"].at[:, ci + 1:].set(jnp.bfloat16(1e30)),
    }
    y_clean, _ = L.attention_decode(p, x, Cfg, base, ci)
    y_dirty, _ = L.attention_decode(p, x, Cfg, garbage, ci)
    assert np.all(np.isfinite(np.asarray(y_dirty)))
    assert np.array_equal(_bits(y_clean), _bits(y_dirty))


def test_attention_decode_window_masks_old_positions():
    """With a sliding window, cache entries older than the window are
    masked out — decoding at ci must ignore positions <= ci - window."""

    class Cfg:
        d_model, n_heads, n_kv_heads, head_dim = 32, 2, 1, 16
        qkv_bias = False
        qk_norm = False
        attn_softcap = None
        rope_theta = 10_000.0
        norm_eps = 1e-6
        policy = "fp32"
        mix_policy = "fp32"

    p = L.attn_init(jax.random.PRNGKey(2), Cfg)
    x = _rand((1, 1, 32), 16)
    T, ci, win = 16, 8, 3
    rng = np.random.default_rng(17)
    cache = {"k": jnp.asarray(rng.standard_normal((1, T, 1, 16)),
                              jnp.bfloat16),
             "v": jnp.asarray(rng.standard_normal((1, T, 1, 16)),
                              jnp.bfloat16)}
    dirty = {
        # corrupt everything outside the window [ci-win+1, ci]
        "k": cache["k"].at[:, :ci - win + 1].set(jnp.bfloat16(1e30)),
        "v": cache["v"].at[:, :ci - win + 1].set(jnp.bfloat16(1e30)),
    }
    y_clean, _ = L.attention_decode(p, x, Cfg, cache, ci, window=win)
    y_dirty, _ = L.attention_decode(p, x, Cfg, dirty, ci, window=win)
    assert np.array_equal(_bits(y_clean), _bits(y_dirty))
