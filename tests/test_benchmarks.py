"""Bench-harness tests: the blocking timer contract, the snapshot
schema round-trip, the compare.py regression matrix, the snapshot CLI
against the committed baselines (the acceptance pin), and smoke-mode
determinism for every registered bench.

Markidis et al. (PAPERS.md) show how easily Tensor-Core speedups
evaporate under measurement error — hence the harness itself is under
test, starting with the fact that an unblocked wall-clock delta times
jax's async *enqueue*, not the compute.
"""
import json
import os

import pytest

from benchmarks import common, compare, run

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def bench_out(tmp_path, monkeypatch):
    """Redirect display-JSON artifacts (and blocksweep's autotune cache)
    away from experiments/bench."""
    out = tmp_path / "bench"
    monkeypatch.setattr(common, "OUT_DIR", str(out))
    return out


# ------------------------------------------------------------- timed()

class _FakeAsync:
    """Stands in for a jax array: counts block_until_ready calls."""

    def __init__(self, counter):
        self.counter = counter

    def block_until_ready(self):
        self.counter["blocks"] += 1


def test_timed_blocks_every_rep_including_warmup():
    counter = {"blocks": 0, "calls": 0}

    def fn():
        counter["calls"] += 1
        # pytree output: blocking must reach nested async leaves
        return {"out": _FakeAsync(counter), "aux": 42}

    out, mean, samples = common.timed(fn, reps=4, warmup=2)
    assert counter["calls"] == 6
    assert counter["blocks"] == 6          # warmup blocks too
    assert len(samples) == 4
    assert mean == pytest.approx(sum(samples) / 4)
    assert all(s >= 0 for s in samples)
    assert out["aux"] == 42


def test_timed_zero_warmup_still_returns_output():
    out, _, samples = common.timed(lambda: 7, reps=2, warmup=0)
    assert out == 7 and len(samples) == 2


def test_record_timed_noise_tracks_sample_jitter(bench_out):
    common.begin_snapshot()
    common.record_timed("m/steady", [1.0, 1.0, 1.0])
    common.record_timed("m/jittery", [1.0, 2.0, 3.0],
                        higher_is_better=True,
                        transform=lambda s: 10.0 / s)
    m = common.end_snapshot()
    assert m["m/steady"]["noise"] == 0.0
    assert m["m/steady"]["kind"] == "measured"
    assert m["m/jittery"]["value"] == pytest.approx(5.0)   # 10 / mean(2)
    # relative sample jitter (std/mean = 0.5) carried through transform
    assert m["m/jittery"]["noise"] == pytest.approx(2.5)


def test_record_rejects_non_finite_values():
    common.begin_snapshot()
    try:
        with pytest.raises(ValueError, match="non-finite"):
            common.record("bad", float("inf"))
        with pytest.raises(ValueError, match="non-finite"):
            common.record("bad", float("nan"))
    finally:
        assert common.end_snapshot() == {}


def test_record_is_noop_outside_snapshot_mode():
    assert not common.snapshot_active()
    common.record("orphan", 1.0)           # must not raise, must not leak
    common.begin_snapshot()
    common.record("kept", 2.0)
    m = common.end_snapshot()
    assert m == {"kept": {"value": 2.0, "unit": "", "kind": "analytic",
                          "higher_is_better": True, "noise": 0.0}}
    assert not common.snapshot_active()


# -------------------------------------------------- schema round-trip

def test_snapshot_schema_roundtrip(tmp_path):
    common.begin_snapshot()
    common.record("a/tflops", 51.0, unit="TF/s")
    common.record("a/latency", 0.2, unit="s", kind="measured",
                  higher_is_better=False, noise=0.01)
    metrics = common.end_snapshot()
    env = {"backend": "cpu", "device_count": 1, "policy": "fp32",
           "git_sha": "deadbeef", "jax_version": "0", "noise_rel": 0.1}
    path = tmp_path / "BENCH_a.json"
    run.write_snapshot(str(path), "a", True, env, metrics)
    snap = compare.load_snapshot(str(path))
    assert snap["schema"] == common.SCHEMA_VERSION
    assert snap["bench"] == "a" and snap["ok"] is True
    assert snap["env"] == env
    assert snap["metrics"] == metrics


def test_load_snapshot_rejects_non_snapshot_json(tmp_path):
    p = tmp_path / "BENCH_bad.json"
    p.write_text('{"title": "a display table, not a snapshot"}')
    with pytest.raises(ValueError, match="not a BENCH snapshot"):
        compare.load_snapshot(str(p))


def test_env_fingerprint_fields():
    env = run.env_fingerprint()
    assert set(env) == {"backend", "device_count", "policy",
                        "jax_version", "git_sha", "noise_rel"}
    assert env["device_count"] >= 1
    assert env["noise_rel"] >= 0.0


# ---------------------------------------------------- compare() matrix

def _metric(value, *, kind="analytic", noise=0.0, higher=True):
    return {"value": value, "unit": "", "kind": kind,
            "higher_is_better": higher, "noise": noise}


def _one(base, cand, **kw):
    (f,) = compare.compare_metrics({"m": base}, {"m": cand}, **kw)
    return f


def test_compare_improvement_passes():
    f = _one(_metric(10.0), _metric(20.0))
    assert f["status"] == "improved"


def test_compare_regression_beyond_noise_fails():
    f = _one(_metric(10.0), _metric(9.0))   # -10% vs 2% floor
    assert f["status"] == "regression"


def test_compare_within_noise_passes():
    assert _one(_metric(10.0), _metric(9.9))["status"] == "ok"
    # wide recorded noise band absorbs a big delta: 3 sigma * 1.0 = 3.0
    f = _one(_metric(10.0, noise=1.0), _metric(8.0, noise=1.0))
    assert f["status"] == "ok"


def test_compare_lower_is_better_flips_direction():
    worse = _one(_metric(1.0, higher=False), _metric(2.0, higher=False))
    assert worse["status"] == "regression"
    better = _one(_metric(2.0, higher=False), _metric(1.0, higher=False))
    assert better["status"] == "improved"


def test_compare_measured_floor_is_wider():
    base = _metric(10.0, kind="measured")
    assert _one(base, _metric(6.0, kind="measured"))["status"] == "ok"
    f = _one(base, _metric(4.0, kind="measured"))  # -60% > 50% floor
    assert f["status"] == "regression"


def test_compare_measured_ungated_across_backends():
    base = _metric(10.0, kind="measured")
    f = _one(base, _metric(1.0, kind="measured"), gate_measured=False)
    assert f["status"] == "ungated"
    # analytic metrics still gate with measured gating off
    f = compare.compare_metrics({"a": _metric(10.0)}, {"a": _metric(1.0)},
                                gate_measured=False)[0]
    assert f["status"] == "regression"


def test_compare_metric_added_and_removed_are_non_gating():
    fs = compare.compare_metrics(
        {"old": _metric(1.0), "both": _metric(1.0)},
        {"new": _metric(1.0), "both": _metric(1.0)})
    by = {f["metric"]: f["status"] for f in fs}
    assert by == {"old": "removed", "new": "added", "both": "ok"}
    assert all(s in compare.NON_GATING for s in by.values())


def test_compare_snapshots_gates_bench_claim_flip():
    env = {"backend": "cpu"}
    base = {"bench": "x", "ok": True, "env": env,
            "metrics": {"m": _metric(1.0)}}
    cand = {"bench": "x", "ok": False, "env": env,
            "metrics": {"m": _metric(1.0)}}
    passed, findings = compare.compare_snapshots(base, cand)
    assert not passed
    assert findings[0]["metric"] == "<bench claim>"
    passed, _ = compare.compare_snapshots(base, dict(cand, ok=True))
    assert passed


def test_compare_snapshots_backend_mismatch_relaxes_measured():
    base = {"bench": "x", "ok": True, "env": {"backend": "tpu"},
            "metrics": {"m": _metric(10.0, kind="measured")}}
    cand = {"bench": "x", "ok": True, "env": {"backend": "cpu"},
            "metrics": {"m": _metric(1.0, kind="measured")}}
    passed, findings = compare.compare_snapshots(base, cand)
    assert passed and findings[0]["status"] == "ungated"


def test_compare_cli_missing_baseline_is_clean_first_run(tmp_path,
                                                         capsys):
    cand = tmp_path / "cand"
    cand.mkdir()
    run.write_snapshot(str(cand / "BENCH_x.json"), "x", True,
                       {"backend": "cpu"}, {"m": _metric(1.0)})
    empty_base = tmp_path / "base"
    empty_base.mkdir()
    rc = compare.main(["--baseline", str(empty_base),
                       "--candidate", str(cand)])
    assert rc == 0
    assert "first-run pass" in capsys.readouterr().out


def test_compare_cli_empty_candidate_dir_errors(tmp_path):
    assert compare.main(["--baseline", str(tmp_path),
                         "--candidate", str(tmp_path)]) == 2


# ------------------------- acceptance pin: CLI + committed baselines

def test_snapshot_cli_matches_committed_baseline_and_gates_perturbation(
        tmp_path, bench_out, capsys):
    """`run --snapshot fig14` must agree with the committed
    BENCH_fig14.json (exit 0) and a perturbed metric must flip the exit
    code — the regression gate demonstrably fires."""
    snap_dir = tmp_path / "snaps"
    assert run.main(["--snapshot", "--snapshot-dir", str(snap_dir),
                     "fig14"]) == 0
    path = snap_dir / "BENCH_fig14.json"
    assert path.exists()
    assert os.path.exists(os.path.join(REPO_ROOT, "BENCH_fig14.json")), \
        "committed baseline missing from repo root"
    assert compare.main(["--baseline", REPO_ROOT,
                         "--candidate", str(snap_dir)]) == 0

    snap = json.loads(path.read_text())
    name = "gemm/4096/tcec_bf16x6/fused+heur/tflops"
    snap["metrics"][name]["value"] *= 0.5      # way beyond the 2% floor
    path.write_text(json.dumps(snap))
    assert compare.main(["--baseline", REPO_ROOT,
                         "--candidate", str(snap_dir)]) == 1
    assert "regression" in capsys.readouterr().out


def test_snapshot_default_set_covers_throughput_benches():
    assert run.SNAPSHOT_DEFAULT == ["fig11", "fig14", "fig14attn",
                                    "blocksweep", "serving"]
    for name in run.SNAPSHOT_DEFAULT:
        assert name in run.BENCHES
        assert os.path.exists(
            os.path.join(REPO_ROOT, f"BENCH_{name}.json")), \
            f"BENCH_{name}.json baseline not committed"


# ------------------------------------------------ smoke determinism

def _snapshot_run(name):
    common.begin_snapshot()
    try:
        ok = run.BENCHES[name].runner()
    finally:
        metrics = common.end_snapshot()
    return ok, metrics


def _analytic(metrics):
    return {k: v for k, v in metrics.items() if v["kind"] == "analytic"}


@pytest.mark.parametrize("name", sorted(run.BENCHES))
def test_bench_smoke_deterministic(name, bench_out):
    """Every registered bench (all pinned-seed, smoke-form entries) must
    pass twice in-process with bit-identical analytic snapshot metrics —
    bench drift can't hide behind flakiness.  Wall-clock (``measured``)
    metrics are exempt by construction."""
    ok1, m1 = _snapshot_run(name)
    ok2, m2 = _snapshot_run(name)
    assert bool(ok1) and bool(ok2)     # some benches return np.bool_
    assert m1, f"{name} records no snapshot metrics"
    assert _analytic(m1) == _analytic(m2)
    for key, m in m1.items():
        assert m["kind"] in ("analytic", "measured"), key
