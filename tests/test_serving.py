"""Serving subsystem: paged KV cache, continuous batching, paged kernel.

The load-bearing contract: with the paged kernel hatch closed (the CPU
default), **greedy engine output is token-identical to the dense-cache
``generate_dense`` path** — the page gather feeds bitwise the same attend
as the dense cache, across transformer / GQA / MLA(+MoE) smoke archs,
same-length batches and mixed-length continuous batching alike.  On top:
scheduler policy units (FIFO admission, LIFO preemption, slot recycling),
sampling units, page-pool units, and the paged decode kernel's interpret-
mode parity + accuracy ordering against an f32 oracle.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import numerics
from repro.configs import get_smoke_config
from repro.kernels import dispatch, tuning
from repro.kernels.tcec_matmul import VMEM_BUDGET
from repro.kernels.tcec_paged_attention import (paged_vmem_bytes,
                                                tcec_paged_attention)
from repro.core.policy import get_policy
from repro.models import get_model
from repro.models import layers as L
from repro.serving import (Engine, PagePool, PagePoolError, SamplingParams,
                           Scheduler, sampling)
from repro.serving.kv_cache import inverse_permutation, permute_pages


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


_PARAMS_CACHE = {}


def _model_and_params(arch):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        _PARAMS_CACHE[arch] = (cfg, model,
                               model.init(jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[arch]


def _prompts(cfg, shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)


# ================================================================ pool

def test_page_pool_alloc_free_roundtrip():
    pool = PagePool(num_pages=8, page_size=4)
    assert pool.num_free == 7            # page 0 reserved (scrap page)
    a = pool.alloc(3)
    assert len(a) == 3 and 0 not in a and pool.num_live == 3
    assert pool.alloc(5) is None         # all-or-nothing
    assert pool.num_free == 4            # failed alloc changed nothing
    pool.free(a)
    assert pool.num_free == 7 and pool.num_live == 0
    with pytest.raises(PagePoolError):
        pool.free(a[:1])                 # double free


def test_page_pool_pages_for():
    pool = PagePool(num_pages=4, page_size=4)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    assert pool.pages_for(0) == 1


def test_page_pool_defrag_compacts_live_pages():
    pool = PagePool(num_pages=10, page_size=2)
    a = pool.alloc(4)
    b = pool.alloc(3)
    pool.free(a)                         # leave holes below b's pages
    mapping = pool.defrag()
    assert sorted(mapping) == sorted(b)
    assert sorted(mapping.values()) == [1, 2, 3]   # compacted to the floor
    assert pool.num_live == 3 and pool.num_free == 6
    c = pool.alloc(6)                    # the holes are allocatable again
    assert c is not None and set(c).isdisjoint(mapping.values())


def test_permute_pages_moves_page_contents():
    pools = {"k": jnp.arange(2 * 4 * 2, dtype=jnp.float32).reshape(2, 4, 2)}
    perm = inverse_permutation({3: 1, 1: 2}, 4)
    out = permute_pages(pools, perm)
    np.testing.assert_array_equal(np.asarray(out["k"][:, 1]),
                                  np.asarray(pools["k"][:, 3]))
    np.testing.assert_array_equal(np.asarray(out["k"][:, 2]),
                                  np.asarray(pools["k"][:, 1]))


# ============================================================= sampling

def test_sample_greedy_is_argmax():
    logits = _rand((3, 32), 0)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3, dtype=jnp.uint32))
    toks = sampling.sample(logits, jnp.zeros(3), jnp.zeros(3, jnp.int32),
                           jnp.ones(3), keys)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_top_k_one_is_argmax_at_any_temperature():
    logits = _rand((4, 64), 1)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4, dtype=jnp.uint32))
    toks = sampling.sample(logits, jnp.full(4, 5.0),
                           jnp.ones(4, jnp.int32), jnp.ones(4), keys)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_top_k_never_leaves_the_top_k():
    logits = _rand((2, 128), 2)
    top8 = set(np.asarray(jnp.argsort(-logits, axis=-1)[:, :8])[0].tolist())
    for seed in range(20):
        keys = jnp.stack([jax.random.PRNGKey(seed)] * 2)
        toks = sampling.sample(logits, jnp.ones(2), jnp.full(2, 8, jnp.int32),
                               jnp.ones(2), keys)
        assert int(toks[0]) in top8


def test_sample_top_p_tiny_keeps_only_the_mode():
    logits = _rand((2, 64), 3)
    for seed in range(10):
        keys = jnp.stack([jax.random.PRNGKey(seed)] * 2)
        toks = sampling.sample(logits, jnp.ones(2), jnp.zeros(2, jnp.int32),
                               jnp.full(2, 1e-6), keys)
        np.testing.assert_array_equal(np.asarray(toks),
                                      np.asarray(jnp.argmax(logits, -1)))


def test_sample_per_row_params_are_independent():
    """A greedy row and a hot sampled row coexist in one call, and a
    row's draw depends only on its own key — not batch composition."""
    logits = _rand((2, 256), 4)
    key = jax.random.PRNGKey(7)
    keys = jnp.stack([key, jax.random.PRNGKey(8)])
    toks = sampling.sample(logits, jnp.asarray([0.0, 1.0]),
                           jnp.zeros(2, jnp.int32), jnp.ones(2), keys)
    assert int(toks[0]) == int(jnp.argmax(logits[0]))
    solo = sampling.sample(logits[1:], jnp.ones(1), jnp.zeros(1, jnp.int32),
                           jnp.ones(1), key[None] * 0 + keys[1:])
    assert int(toks[1]) == int(solo[0])


# ============================================================ scheduler

def _mk_sched(num_pages=16, page_size=4, max_slots=2):
    return Scheduler(PagePool(num_pages, page_size), max_slots)


def test_scheduler_admits_fifo_into_free_slots():
    s = _mk_sched(max_slots=2)
    r1 = s.add([1] * 4, SamplingParams())
    r2 = s.add([2] * 4, SamplingParams())
    r3 = s.add([3] * 4, SamplingParams())
    admitted = s.admit()
    assert [r.rid for r in admitted] == [r1.rid, r2.rid]
    assert admitted[0].slot == 0 and admitted[1].slot == 1
    assert [r.rid for r in s.waiting] == [r3.rid]
    # slot recycling: finishing r1 lets r3 in, reusing slot 0
    s.finish(s.running[0])
    assert s.admit()[0].rid == r3.rid
    assert s.running[0].rid == r3.rid


def test_scheduler_admission_is_strict_fifo_no_bypass():
    s = _mk_sched(num_pages=4, page_size=4, max_slots=2)   # 3 free pages
    big = s.add([0] * 13, SamplingParams())    # needs 4 pages: can't fit
    s.add([0] * 2, SamplingParams())           # would fit, but queued behind
    assert s.admit() == []                     # head blocks the line
    assert s.waiting[0] is big


def test_scheduler_preempts_youngest_and_requeues_front():
    s = _mk_sched(num_pages=9, page_size=4, max_slots=2)   # 8 free pages
    a = s.add([0] * 8, SamplingParams())       # 3 pages
    b = s.add([0] * 8, SamplingParams())       # 3 pages
    s.admit()
    assert s.pool.num_free == 2
    assert s.pool.alloc(2) is not None         # drain the pool
    ok = s.grow(a)                             # a needs a page -> evict b
    assert ok and b.slot is None and b.n_preemptions == 1
    assert s.waiting[0] is b and len(a.pages) == 4
    assert list(s.running) == [a.slot]
    # b's generated-so-far tokens ride along into its re-prefill prompt
    b.out.extend([5, 6])
    assert b.full_sequence == [0] * 8 + [5, 6]


def test_scheduler_grow_fails_only_when_alone_and_dry():
    s = _mk_sched(num_pages=3, page_size=4, max_slots=1)
    a = s.add([0] * 4, SamplingParams())
    s.admit()
    assert s.pool.alloc(s.pool.num_free) is not None
    assert not s.grow(a)                       # nobody left to evict


# ===================================================== paged kernel

def _paged_case(B=3, Hkv=2, rep=4, hd=64, hdv=64, ps=8, maxp=5, seed=0):
    rng = np.random.default_rng(seed)
    NP = 1 + B * maxp
    kp = jnp.asarray(rng.standard_normal((NP, ps, Hkv, hd)), jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((NP, ps, Hkv, hdv)), jnp.bfloat16)
    q = jnp.asarray(rng.standard_normal((B, Hkv * rep, hd)), jnp.float32)
    bt = jnp.asarray(
        rng.permutation(np.arange(1, NP)).reshape(B, maxp), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, maxp * ps, B), jnp.int32)
    return q, kp, vp, bt, lengths


def _gather(pages, bt):
    B, maxp = bt.shape
    g = pages[bt]
    return g.reshape(B, maxp * g.shape[2], g.shape[3], g.shape[4])


def _f32_oracle(q, kp, vp, bt, lengths, window=0):
    """Exact f32 paged decode attention (the accuracy yardstick)."""
    kg = _gather(kp, bt).astype(jnp.float32)
    vg = _gather(vp, bt).astype(jnp.float32)
    B, T, Hkv, hd = kg.shape
    rep = q.shape[1] // Hkv
    qg = q.reshape(B, Hkv, rep, hd)
    s = jnp.einsum("bhrd,bthd->bhrt", qg, kg) / np.sqrt(hd)
    d = (lengths[:, None] - 1) - jnp.arange(T)
    ok = d >= 0
    if window:
        ok &= d < window
    s = jnp.where(ok[:, None, None, :], s, L.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrt,bthd->bhrd", p, vg)
    return o.reshape(B, q.shape[1], -1)


def _bf16_fallback(q, kp, vp, bt, lengths, window=0):
    """The engine's XLA fallback math: page gather + the dense decode
    attend (bf16 cache dots — models.layers._decode_attend)."""
    class Cfg:
        attn_softcap = None
    o = L._decode_attend(q[:, None], _gather(kp, bt), _gather(vp, bt),
                         Cfg(), lengths - 1, window)
    return o[:, 0]


@pytest.mark.parametrize("g", [1, 2, 4, 5])
def test_paged_kernel_matches_f32_oracle_across_gather_widths(g):
    q, kp, vp, bt, lengths = _paged_case(seed=10)
    ref = _f32_oracle(q, kp, vp, bt, lengths)
    out = tcec_paged_attention(q, kp, vp, bt, lengths, pages_per_step=g,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_recovers_precision_the_bf16_decode_path_discards():
    """The paper's point, applied at decode time: the kernel TCEC-splits
    the f32 query and probs where the dense path rounds both to bf16 —
    so the kernel must sit strictly closer to the f32 oracle, while
    staying within bf16-level distance of the fallback."""
    q, kp, vp, bt, lengths = _paged_case(B=4, maxp=4, seed=11)
    ref = np.asarray(_f32_oracle(q, kp, vp, bt, lengths))
    fb = np.asarray(_bf16_fallback(q, kp, vp, bt, lengths))
    out = np.asarray(tcec_paged_attention(q, kp, vp, bt, lengths,
                                          pages_per_step=2, interpret=True))
    err_kernel = np.max(np.abs(out - ref))
    err_fallback = np.max(np.abs(fb - ref))
    assert err_kernel < err_fallback / 4, (err_kernel, err_fallback)
    np.testing.assert_allclose(out, fb, rtol=5e-2, atol=5e-2)


def test_paged_kernel_window_and_empty_rows():
    q, kp, vp, bt, lengths = _paged_case(seed=12)
    ref = _f32_oracle(q, kp, vp, bt, lengths, window=5)
    out = tcec_paged_attention(q, kp, vp, bt, lengths, window=5,
                               pages_per_step=2, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # a slot with no valid tokens returns zeros, never NaN
    z = tcec_paged_attention(q, kp, vp, bt, jnp.zeros_like(lengths),
                             pages_per_step=2, interpret=True)
    assert bool(jnp.all(z == 0.0))


def test_paged_kernel_ignores_stale_garbage_in_recycled_pages():
    """Masking is a select, not an additive bias: non-finite stale data in
    pages beyond the sequence length must not poison the softmax."""
    q, kp, vp, bt, lengths = _paged_case(B=2, maxp=3, seed=13)
    kp = kp.at[int(bt[0, 2]), :].set(jnp.inf)     # garbage past length
    vp = vp.at[int(bt[0, 2]), :].set(jnp.nan)
    short = jnp.asarray([3, 5], jnp.int32)        # well inside page 0
    out = tcec_paged_attention(q, kp, vp, bt, short, pages_per_step=1,
                               interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))


# -------------------------------------------- dispatch + tuning wiring

def test_paged_dispatch_eligibility_and_hatches(monkeypatch):
    q, kp, vp, bt, lengths = _paged_case(seed=14)
    pol = "tcec_bf16x6"
    with numerics.use(force=True, interpret=True, paged_block=2):
        assert dispatch.attention_decode_eligible(q, kp, vp, policy=pol)
        out = dispatch.attention_decode(q, kp, vp, bt, lengths, policy=pol)
        assert out is not None and out.shape == (3, 8, 64)
        # granular hatch
        with numerics.use(paged_attention=False):
            assert dispatch.attention_decode(q, kp, vp, bt, lengths,
                                             policy=pol) is None
        # wholesale hatch
        with numerics.use(enabled=False):
            assert dispatch.attention_decode(q, kp, vp, bt, lengths,
                                             policy=pol) is None
        # plain policies stay on XLA
        assert not dispatch.attention_decode_eligible(q, kp, vp,
                                                      policy="bf16")
    # off-TPU without force: decline
    assert not dispatch.attention_decode_eligible(q, kp, vp, policy=pol)
    # env hatch round-trip through the process defaults
    monkeypatch.setenv("REPRO_DISABLE_PAGED_ATTN", "1")
    assert not numerics.reload_env_defaults().paged_attention
    monkeypatch.setenv("REPRO_DISABLE_PAGED_ATTN", "0")
    assert numerics.reload_env_defaults().paged_attention
    monkeypatch.delenv("REPRO_DISABLE_PAGED_ATTN")
    numerics.reload_env_defaults()


def test_paged_dispatch_under_mesh_routes_or_declines():
    """Under a mesh the paged kernel runs per shard through shard_map
    (kernels/shmap.py); the knob / an unsupported spec decline."""
    from jax.sharding import Mesh
    from repro.kernels import shmap
    from repro.parallel import ctx
    q, kp, vp, bt, lengths = _paged_case(seed=15)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("model",))
    with numerics.use(force=True, interpret=True):
        ref = dispatch.attention_decode(q, kp, vp, bt, lengths,
                                        policy="tcec_bf16x6")
        with ctx.use_mesh(mesh):
            assert dispatch.attention_decode_eligible(
                q, kp, vp, policy="tcec_bf16x6")
            n0 = shmap.counters()["paged"]
            out = dispatch.attention_decode(q, kp, vp, bt, lengths,
                                            policy="tcec_bf16x6")
            assert out is not None and shmap.counters()["paged"] == n0 + 1
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
            with numerics.use(shard_map=False):
                assert not dispatch.attention_decode_eligible(
                    q, kp, vp, policy="tcec_bf16x6")

        class _FakeMesh:                   # Hkv not divisible by the axis
            shape = {"model": max(3, kp.shape[2] + 1)}
            axis_names = ("model",)
        with ctx.use_mesh(_FakeMesh()):
            assert not dispatch.attention_decode_eligible(
                q, kp, vp, policy="tcec_bf16x6")


def test_paged_kernel_matches_fused_dispatch_inside_model_layer():
    """attention_decode_paged under forced dispatch (fused kernel) agrees
    with its own gather fallback to kernel tolerance."""
    cfg = get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    lp = jax.tree.map(lambda a: a[0], params["dense_blocks"])["attn"]
    from repro.models import lm
    pools = lm.init_paged_cache(cfg, 9, 4)["dense_blocks"]
    pool = jax.tree.map(lambda a: a[0], pools)
    rng = np.random.default_rng(3)
    pool = jax.tree.map(
        lambda a: jnp.asarray(rng.standard_normal(a.shape), a.dtype), pool)
    x = _rand((2, 1, cfg.d_model), 16)
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    lengths = jnp.asarray([6, 11], jnp.int32)
    ref, _ = L.attention_decode_paged(lp, x, cfg, pool, bt, lengths)
    with numerics.use(force=True, interpret=True, min_dim=0,
                           paged_block=2):
        out, _ = L.attention_decode_paged(lp, x, cfg, pool, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


def test_paged_autotune_namespace_roundtrip(tmp_path):
    calls = []

    def fake_measure(g):
        calls.append(g)
        return 1.0 + abs(g - 4) / 1e3          # prefers 4 pages per step

    cache = tuning.BlockCache(path=str(tmp_path / "tune.json"))
    g, meta = tuning.autotune_paged(4, 2, 4, 16, 16, 64, 64, "tcec_bf16x6",
                                    measure=fake_measure, cache=cache)
    assert meta["source"] == "measured" and g == 4
    n = len(calls)
    g2, meta2 = tuning.autotune_paged(4, 2, 4, 16, 16, 64, 64,
                                      "tcec_bf16x6", measure=fake_measure,
                                      cache=cache)
    assert g2 == g and meta2["source"] == "cache" and len(calls) == n
    key = tuning.paged_cache_key(4, 2, 4, 16, 16, 64, 64, "tcec_bf16x6",
                                 jax.default_backend())
    assert "/paged/" in key
    assert key != tuning.attn_cache_key(4, 2, 4, 16, 16, 64, 64,
                                        "tcec_bf16x6",
                                        jax.default_backend())


def test_paged_candidates_respect_vmem():
    pol = get_policy("tcec_bf16x6")
    cands = tuning.paged_candidate_blocks(64, 16, 8, 64, 64, "tcec_bf16x6")
    assert cands and all(
        paged_vmem_bytes(g, 16, 8, 64, 64, pol) <= VMEM_BUDGET
        for g in cands)
    assert all(g <= 64 for g in cands)
    g = tuning.paged_heuristic_block(64, 16, 8, 64, 64, "tcec_bf16x6")
    assert g * 16 >= 128                       # reaches the 128-lane MXU
    assert tuning.paged_candidate_blocks(2, 4, 1, 64, 64,
                                         "tcec_bf16x6") == [2, 1]


# ================================================= engine <-> dense parity

PARITY_ARCHS = ["qwen3-0.6b", "gemma2-9b", "deepseek-v3-671b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_engine_greedy_token_identical_to_dense_generate(arch):
    """The acceptance contract: transformer / GQA+window+softcap / MLA+MoE
    — greedy engine output == dense-cache reference, token for token."""
    from repro.launch.serve import generate, generate_dense
    cfg, model, params = _model_and_params(arch)
    prompts = _prompts(cfg, (2, 9), seed=5)
    dense = np.asarray(generate_dense(cfg, params, prompts, 6))
    eng = np.asarray(generate(cfg, params, prompts, 6))
    np.testing.assert_array_equal(dense, eng)


def test_engine_mixed_lengths_match_per_request_dense():
    """Continuous batching must not change anyone's tokens: requests of
    different lengths decoding side by side each match their own
    single-request dense run."""
    from repro.launch.serve import generate_dense
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(6)
    lens = [5, 9, 13]
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in lens]
    engine = Engine(cfg, params, max_slots=3, num_pages=64, page_size=4)
    rids = [engine.add_request(p, SamplingParams(max_tokens=6))
            for p in prompts]
    out = engine.run()
    for p, rid in zip(prompts, rids):
        ref = np.asarray(generate_dense(
            cfg, params, jnp.asarray(p, jnp.int32)[None], 6))[0]
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))


def test_engine_slot_recycling_more_requests_than_slots():
    from repro.launch.serve import generate_dense
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, 4 + i) for i in range(5)]
    engine = Engine(cfg, params, max_slots=2, num_pages=64, page_size=4)
    rids = [engine.add_request(p, SamplingParams(max_tokens=5))
            for p in prompts]
    out = engine.run()
    assert not engine.sched.has_work and engine.pool.num_live == 0
    for p, rid in zip(prompts, rids):
        ref = np.asarray(generate_dense(
            cfg, params, jnp.asarray(p, jnp.int32)[None], 5))[0]
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))


def test_engine_preemption_recovers_and_stays_token_identical():
    """A pool too small for two residents forces a preemption; the victim
    re-prefills (prompt + generated so far) and still produces exactly its
    solo-run tokens."""
    from repro.launch.serve import generate_dense
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(8)
    p1 = rng.integers(0, cfg.vocab_size, 4)
    p2 = rng.integers(0, cfg.vocab_size, 4)
    engine = Engine(cfg, params, max_slots=2, num_pages=7, page_size=4,
                    max_pages_per_slot=6)
    r1 = engine.add_request(p1, SamplingParams(max_tokens=12))
    r2 = engine.add_request(p2, SamplingParams(max_tokens=12))
    out = engine.run()
    preempts = [engine._requests[r].n_preemptions for r in (r1, r2)]
    assert sum(preempts) >= 1, preempts
    for p, rid in [(p1, r1), (p2, r2)]:
        ref = np.asarray(generate_dense(
            cfg, params, jnp.asarray(p, jnp.int32)[None], 12))[0]
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))


def test_engine_stop_tokens_and_max_tokens():
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(9)
    p = rng.integers(0, cfg.vocab_size, 6)
    engine = Engine(cfg, params, max_slots=1, num_pages=32, page_size=4)
    rid = engine.add_request(p, SamplingParams(max_tokens=8))
    free_run = engine.run()[rid]
    assert len(free_run) == 8
    # stop on the 3rd greedy token: output is the first two, stop excluded
    engine2 = Engine(cfg, params, max_slots=1, num_pages=32, page_size=4)
    rid2 = engine2.add_request(
        p, SamplingParams(max_tokens=8, stop_tokens=(free_run[2],)))
    stopped = engine2.run()[rid2]
    assert stopped == free_run[:2]
    # stop on the very first token: empty output, slot still recycled
    engine3 = Engine(cfg, params, max_slots=1, num_pages=32, page_size=4)
    rid3 = engine3.add_request(
        p, SamplingParams(max_tokens=8, stop_tokens=(free_run[0],)))
    assert engine3.run()[rid3] == []
    assert engine3.pool.num_live == 0


def test_engine_defrag_is_output_invariant():
    from repro.launch.serve import generate_dense
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(10)
    p1 = rng.integers(0, cfg.vocab_size, 7)
    p2 = rng.integers(0, cfg.vocab_size, 5)
    engine = Engine(cfg, params, max_slots=2, num_pages=32, page_size=4)
    r1 = engine.add_request(p1, SamplingParams(max_tokens=9))
    r2 = engine.add_request(p2, SamplingParams(max_tokens=4))
    for _ in range(5):
        engine.step()                     # r2 finishes -> holes in the pool
    engine.defragment()
    while engine.sched.has_work:
        engine.step()
    ref = np.asarray(generate_dense(
        cfg, params, jnp.asarray(p1, jnp.int32)[None], 9))[0]
    np.testing.assert_array_equal(ref,
                                  np.asarray(engine._requests[r1].out))


def test_engine_finishes_preempted_request_past_the_length_cap():
    """Regression (review finding): a request preempted after *generating*
    its way to the per-slot cap must be finished from the queue — its
    re-admission would need more pages than a block-table row holds
    (add_request's cap check only guards initial prompts)."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(14)
    p = rng.integers(0, cfg.vocab_size, 4)
    engine = Engine(cfg, params, max_slots=1, num_pages=32, page_size=4,
                    max_pages_per_slot=2)
    rid = engine.add_request(p, SamplingParams(max_tokens=20))
    req = engine._requests[rid]
    # simulate the preempted state: generated up to the cap, back in queue
    req.out.extend(int(t) for t in
                   rng.integers(0, cfg.vocab_size, 2 * 4 - len(p)))
    out = engine.run()
    assert engine._requests[rid].finished and not engine.sched.has_work
    assert len(out[rid]) == 2 * 4 - len(p)     # nothing generated on top


def test_engine_preemption_keeps_the_sampled_key_stream_aligned():
    """Regression (review finding): the decode step's split order must
    match the prefill draw's (`key, sub = split(key)`), or a preemption's
    re-prefill resumes a sampled request's stream on the wrong side of
    the split."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(15)
    p = rng.integers(0, cfg.vocab_size, 4)
    sp = SamplingParams(temperature=0.9, top_k=16, max_tokens=10, seed=21)
    solo = Engine(cfg, params, max_slots=1, num_pages=32, page_size=4)
    ref = solo.run([p], sp)
    # tight pool: the sampled request (younger) gets preempted mid-stream
    eng = Engine(cfg, params, max_slots=2, num_pages=7, page_size=4,
                 max_pages_per_slot=6)
    eng.add_request(rng.integers(0, cfg.vocab_size, 4),
                    SamplingParams(max_tokens=12))
    rid = eng.add_request(p, sp)
    out = eng.run()
    assert eng._requests[rid].n_preemptions >= 1
    assert out[rid] == list(ref.values())[0]


def test_engine_sampled_stream_independent_of_batching():
    """A request's sampled tokens depend on its own seed, not on what else
    shares the batch (per-request PRNG streams)."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(11)
    p = rng.integers(0, cfg.vocab_size, 6)
    sp = SamplingParams(temperature=0.8, top_k=32, max_tokens=6, seed=42)
    solo = Engine(cfg, params, max_slots=1, num_pages=32, page_size=4)
    a = solo.run([p], sp)
    busy = Engine(cfg, params, max_slots=2, num_pages=64, page_size=4)
    rid = busy.add_request(p, sp)
    busy.add_request(rng.integers(0, cfg.vocab_size, 9),
                     SamplingParams(temperature=1.0, max_tokens=6, seed=3))
    b = busy.run()
    assert list(a.values())[0] == b[rid]


def test_engine_rejects_unsupported_family_and_oversized_prompt():
    cfg, model, params = _model_and_params("mamba2-130m")
    with pytest.raises(ValueError):
        Engine(cfg, params)
    cfg2, model2, params2 = _model_and_params("qwen3-0.6b")
    engine = Engine(cfg2, params2, max_slots=1, num_pages=32, page_size=4,
                    max_pages_per_slot=2)
    with pytest.raises(ValueError):
        engine.add_request(list(range(16)), SamplingParams())


def test_generate_wrapper_keeps_legacy_shape_and_determinism():
    """Back-compat: (B, P) -> (B, gen_len), deterministic, for both the
    engine-backed families and the dense fallback."""
    from repro.launch.serve import generate
    for arch in ["qwen3-0.6b", "mamba2-130m"]:
        cfg, model, params = _model_and_params(arch)
        prompts = _prompts(cfg, (2, 4), seed=12)
        a = generate(cfg, params, prompts, gen_len=5)
        b = generate(cfg, params, prompts, gen_len=5)
        assert a.shape == (2, 5)
        assert jnp.array_equal(a, b)


def test_prefill_is_single_shot_not_a_decode_loop():
    """The engine's prompt path is ONE jitted sequence-level forward per
    admitted batch — not O(P) decode steps (the legacy loop's shape)."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    prompts = _prompts(cfg, (3, 9), seed=13)
    engine = Engine(cfg, params, max_slots=3, num_pages=64, page_size=4)
    for i in range(3):
        engine.add_request(np.asarray(prompts[i]),
                           SamplingParams(max_tokens=4))
    engine.run()
    assert engine.n_prefills == 1          # same padded length -> one batch
    assert engine.n_decode_steps <= 4      # never P + gen steps
