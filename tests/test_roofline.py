"""Direct coverage for repro.launch.roofline — load/fmt_bytes/table
generation/snapshot metrics from a fixture experiments/dryrun record
set (it was the only launch/ module with no tests of its own)."""
import json

import pytest

from repro.launch import roofline


def _ok_rec(arch="qwen3-0.6b", shape="train_4k", mesh="16x16",
            compute=0.5, memory=0.25, collective=0.125):
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get).replace("_s", "")
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
        "kind": "train", "compile_s": 12.0,
        "roofline": terms, "bottleneck": dom,
        "roofline_fraction": compute / max(terms.values()),
        "useful_flops_ratio": 0.333,
        "memory": {"argument_size_in_bytes": 2 * 2**30,
                   "temp_size_in_bytes": 5 * 2**30},
        "collectives": {"per_device_bytes": 3.2e9,
                        "counts": {"all-reduce": 4, "all-gather": 0}},
    }


@pytest.fixture
def dryrun_dir(tmp_path):
    recs = [
        _ok_rec(),
        _ok_rec(shape="prefill_32k", compute=0.1, memory=0.8),
        {"arch": "qwen3-0.6b", "shape": "long_500k", "mesh": "16x16",
         "status": "skip", "reason": "full attention @500k"},
        {"arch": "zamba2-1.2b", "shape": "train_4k", "mesh": "16x16",
         "status": "error", "error": "OOM during lowering" + "x" * 60},
        _ok_rec(mesh="2x16x16"),   # other mesh: dryrun table only
    ]
    for i, r in enumerate(recs):
        (tmp_path / f"cell{i}.json").write_text(json.dumps(r))
    return tmp_path


def test_load_reads_sorted_records(dryrun_dir):
    recs = roofline.load(str(dryrun_dir))
    assert len(recs) == 5
    assert [r["status"] for r in recs] == ["ok", "ok", "skip", "error",
                                          "ok"]
    assert roofline.load(str(dryrun_dir / "nope")) == []


def test_fmt_bytes_thresholds():
    assert roofline.fmt_bytes(1.5e12) == "1.50T"
    assert roofline.fmt_bytes(2.5e9) == "2.50G"
    assert roofline.fmt_bytes(3.0e6) == "3.0M"
    assert roofline.fmt_bytes(0) == "0.0M"


def test_roofline_table_orders_shapes_and_marks_statuses(dryrun_dir):
    rows = roofline.roofline_table(roofline.load(str(dryrun_dir)),
                                   mesh="16x16")
    by_arch = [(r[0], r[1]) for r in rows]
    # SHAPE_ORDER drives row order within an arch; 2x16x16 cell excluded
    assert by_arch == [("qwen3-0.6b", "train_4k"),
                       ("qwen3-0.6b", "prefill_32k"),
                       ("qwen3-0.6b", "long_500k"),
                       ("zamba2-1.2b", "train_4k")]
    ok_row = rows[0]
    assert ok_row[2:7] == ["0.500", "0.250", "0.125", "compute", "1.00"]
    assert roofline.IMPROVE_HINTS["compute"][:20] in ok_row[8] + " " * 60
    mem_row = rows[1]
    assert mem_row[5] == "memory" and mem_row[6] == "0.12"
    assert "SKIP" in rows[2][2]
    assert rows[3][2] == "ERROR"
    assert rows[3][8] == ("OOM during lowering" + "x" * 60)[:40]


def test_dryrun_table_covers_both_meshes_and_errors(dryrun_dir):
    rows = roofline.dryrun_table(roofline.load(str(dryrun_dir)))
    assert len(rows) == 5
    ok_row = next(r for r in rows if r[0] == "qwen3-0.6b"
                  and r[2] == "16x16" and r[1] == "train_4k")
    assert ok_row[4] == "12s"
    assert ok_row[5] == "2.00" and ok_row[6] == "5.0"       # GiB cols
    assert ok_row[7] == "3.20G"
    assert ok_row[8] == "all-reduce:4"                      # zero dropped
    err_row = next(r for r in rows if r[0] == "zamba2-1.2b")
    assert err_row[3] == "error" and err_row[8].startswith("OOM")


def test_md_table_shape():
    txt = roofline.md_table(["a", "b"], [[1, 2], [3, 4]])
    lines = txt.splitlines()
    assert lines[0] == "| a | b |"
    assert lines[1] == "|---|---|"
    assert lines[2] == "| 1 | 2 |" and len(lines) == 4


def test_snapshot_metrics_ok_cells_only(dryrun_dir):
    metrics = roofline.snapshot_metrics(roofline.load(str(dryrun_dir)))
    assert set(metrics) == {
        "roofline/qwen3-0.6b/train_4k/16x16/fraction",
        "roofline/qwen3-0.6b/train_4k/16x16/useful_flops",
        "roofline/qwen3-0.6b/prefill_32k/16x16/fraction",
        "roofline/qwen3-0.6b/prefill_32k/16x16/useful_flops",
        "roofline/qwen3-0.6b/train_4k/2x16x16/fraction",
        "roofline/qwen3-0.6b/train_4k/2x16x16/useful_flops",
    }
    m = metrics["roofline/qwen3-0.6b/prefill_32k/16x16/fraction"]
    assert m["value"] == pytest.approx(0.125)
    assert m["kind"] == "analytic" and m["higher_is_better"]
    assert roofline.snapshot_metrics([]) == {}


def test_main_writes_report(dryrun_dir, tmp_path, capsys):
    out = tmp_path / "report.md"
    roofline.main(["--dir", str(dryrun_dir), "--out", str(out)])
    txt = out.read_text()
    assert "3 ok / 1 skip / 1 error of 5 cells" in txt
    assert "§Roofline" in txt and "§Dry-run" in txt
    assert "| qwen3-0.6b | train_4k |" in txt
    assert capsys.readouterr().out.strip() == txt.strip()
