"""Docs smoke test: every ```python fence in the documentation must execute
against the current APIs — docs that drift from the code fail tier-1.

Shapes in doc examples are kept small on purpose; this runs on CPU in a
few seconds. Non-runnable snippets belong in ```text fences.
"""
import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", "docs/api.md", "docs/architecture.md",
        "docs/numerics.md", "docs/kernels.md", "docs/parallel.md",
        "docs/serving.md", "docs/robustness.md", "docs/observability.md",
        "benchmarks/README.md"]
EXAMPLES = ["examples/numerics_tour.py", "examples/shard_tour.py"]
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _blocks():
    out = []
    for rel in DOCS:
        path = os.path.join(ROOT, rel)
        assert os.path.exists(path), f"documented file missing: {rel}"
        with open(path) as f:
            text = f.read()
        for i, code in enumerate(_FENCE.findall(text)):
            out.append(pytest.param(rel, code, id=f"{rel}#{i}"))
    return out


def test_doc_suite_exists():
    for rel in DOCS:
        assert os.path.exists(os.path.join(ROOT, rel)), rel


@pytest.mark.parametrize("rel,code", _blocks())
def test_doc_example_runs(rel, code):
    """Each block runs in its own interpreter so examples stay
    self-contained (no hidden state between fences)."""
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=ROOT, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert r.returncode == 0, f"{rel} example failed:\n{r.stderr[-2000:]}"


def test_readme_policy_table_matches_code():
    """The README policy table must list exactly the registered policies."""
    from repro.core import POLICIES
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for name in POLICIES:
        assert f"`{name}`" in readme, f"policy {name} missing from README"


@pytest.mark.parametrize("rel", EXAMPLES)
def test_registered_example_runs(rel):
    """Examples registered in the docs suite must execute end to end."""
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, rel)], capture_output=True,
        text=True, cwd=ROOT, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert r.returncode == 0, f"{rel} failed:\n{r.stderr[-2000:]}"


def test_doc_env_knobs_match_registry():
    """Every REPRO_* name the docs mention must be a registered env var,
    and the two knob-table homes (README + docs/api.md) must document the
    full registry."""
    from repro.numerics import ENV_VARS
    mentioned = {}
    for rel in DOCS:
        with open(os.path.join(ROOT, rel)) as f:
            mentioned[rel] = set(re.findall(r"REPRO_[A-Z0-9_]+", f.read()))
        unknown = mentioned[rel] - set(ENV_VARS)
        assert not unknown, f"{rel} documents unknown env knob(s) {unknown}"
    for rel in ("README.md", "docs/api.md"):
        missing = set(ENV_VARS) - mentioned[rel]
        assert not missing, f"{rel} knob table is missing {missing}"
