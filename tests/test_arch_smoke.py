"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement). Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import get_model

B, S = 2, 32


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, cfg.n_frontend_tokens + S)),
            jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.frontend_dim)), jnp.float32)
    return batch


ALL_ARCHS = list_archs()


def test_all_ten_archs_registered():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, np.random.default_rng(0))
    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, float(loss))
    assert metrics["tokens"] > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, np.random.default_rng(1))

    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), arch
    # a step of naive SGD must change the loss
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    l0 = float(model.loss_fn(params, batch)[0])
    l1 = float(model.loss_fn(new_params, batch)[0])
    assert l1 != l0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 16)
    if cfg.family == "audio":
        from repro.models import encdec_lm
        frames = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, 8, cfg.frontend_dim))
        cache = encdec_lm.prefill_cross(params, frames, cfg, cache)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = model.decode_step(params, cache, tok, 0)
    logits2, _ = model.decode_step(params, cache, tok + 1, 1)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


def test_ssd_chunked_matches_sequential_oracle():
    from repro.models import ssd
    cfg = get_smoke_config("mamba2-130m").replace(policy="fp32")
    p = ssd.ssd_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    y_chunk = ssd.ssd_layer(p, x, cfg)
    y_seq = ssd.ssd_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)


def test_gemma2_local_global_pattern():
    from repro.models.lm import layer_windows
    cfg = get_smoke_config("gemma2-9b")
    w = layer_windows(cfg, 4)
    assert w[0] == cfg.sliding_window and w[1] == 0
    assert w[2] == cfg.sliding_window and w[3] == 0


def test_policy_knob_changes_numerics_but_not_semantics():
    """The paper's technique is a drop-in: same architecture, same loss
    landscape to ~fp32 accuracy under tcec_bf16x6, visibly different under
    plain bf16."""
    cfg32 = get_smoke_config("qwen3-0.6b").replace(policy="fp32")
    cfg6 = cfg32.replace(policy="tcec_bf16x6")
    cfgb = cfg32.replace(policy="bf16")
    m32, m6, mb = get_model(cfg32), get_model(cfg6), get_model(cfgb)
    params = m32.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg32, np.random.default_rng(2))
    l32 = float(m32.loss_fn(params, batch)[0])
    l6 = float(m6.loss_fn(params, batch)[0])
    lb = float(mb.loss_fn(params, batch)[0])
    assert abs(l6 - l32) < 10 * abs(lb - l32) + 1e-6
    assert abs(l6 - l32) < 1e-3
