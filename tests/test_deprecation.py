"""The pre-`repro.numerics` entry points survive as deprecation shims.

Each old entry point must (a) emit exactly one DeprecationWarning naming
its replacement and (b) still work by delegating to the new surface.  CI
runs this file under ``-W error::DeprecationWarning``: the ``pytest.warns``
blocks absorb the expected warnings, so any *unexpected* deprecation —
from the shims or from internal code accidentally still calling them —
fails the build.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import numerics
from repro.kernels import dispatch, ops, tuning


def _one_deprecation(match):
    return pytest.warns(DeprecationWarning, match=match)


def test_override_warns_and_delegates():
    with _one_deprecation("repro.numerics.use"):
        cm = dispatch.override(min_dim=5, force=True)
    with cm as cfg:
        assert isinstance(cfg, numerics.NumericsConfig)
        assert numerics.active().min_dim == 5 and numerics.active().force
    assert numerics.active().min_dim == numerics.NumericsConfig.from_env().min_dim


def test_config_warns_and_returns_active():
    with numerics.use(min_dim=17):
        with _one_deprecation("repro.numerics.active"):
            cfg = dispatch.config()
        assert cfg.min_dim == 17


def test_reload_config_warns_and_delegates(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_MIN_DIM", "64")
    try:
        with _one_deprecation("reload_env_defaults"):
            assert dispatch.reload_config().min_dim == 64
    finally:
        monkeypatch.delenv("REPRO_PALLAS_MIN_DIM")
        numerics.reload_env_defaults()


def test_env_flag_warns_and_parses(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    with _one_deprecation("repro.numerics.env_value"):
        assert dispatch.env_flag("REPRO_FORCE_PALLAS") is True
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "0")
    with _one_deprecation("repro.numerics.env_value"):
        assert dispatch.env_flag("REPRO_FORCE_PALLAS") is False


def test_dispatch_config_class_warns_and_aliases():
    with _one_deprecation("NumericsConfig"):
        cls = dispatch.DispatchConfig
    assert cls is numerics.NumericsConfig
    with _one_deprecation("NumericsConfig"):
        cfg = dispatch.DispatchConfig.from_env({"REPRO_DISABLE_PALLAS": "1"})
    assert not cfg.enabled


def test_pick_block_warns_and_delegates():
    with _one_deprecation("heuristic_block"):
        blk = ops.pick_block(512, 512, 512, "tcec_bf16x6")
    assert blk == tuning.heuristic_block(512, 512, 512, "tcec_bf16x6")


def test_old_surface_still_routes_dispatch():
    """End to end through the shims: override() still flips the dispatch
    path, exactly like the new context (delegation, not a fork)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    from repro.core.policy import policy_mm
    with _one_deprecation("repro.numerics.use"):
        cm = dispatch.override(force=True, interpret=True, min_dim=0,
                               block=(128, 128, 128))
    with cm:
        y_old = policy_mm(a, b, "tcec_bf16x6")
    with numerics.use(force=True, interpret=True, min_dim=0,
                      block=(128, 128, 128)):
        y_new = policy_mm(a, b, "tcec_bf16x6")
    assert np.array_equal(np.asarray(y_old), np.asarray(y_new))


def test_shmap_calls_warns_and_views_registry():
    """The old ``shmap.CALLS`` dict survives as a read-only live view of
    the registry counter behind :func:`shmap.counters`."""
    from repro.kernels import shmap
    with _one_deprecation("repro.kernels.shmap.counters"):
        calls = shmap.CALLS
    assert dict(calls) == shmap.counters()
    before = shmap.counters()["matmul"]
    shmap._bump("matmul")
    assert calls["matmul"] == before + 1     # live, not a snapshot
    with pytest.raises(KeyError):
        calls["nope"]


def test_shmap_reset_calls_warns_and_delegates():
    from repro.kernels import shmap
    shmap._bump("paged")
    assert shmap.counters()["paged"] >= 1
    with _one_deprecation("reset_counters"):
        shmap.reset_calls()
    assert shmap.counters() == {k: 0 for k in shmap.KERNELS}


def test_internal_call_sites_are_warning_free():
    """The migrated internals must never touch a shim: a full dispatch
    round-trip (forced kernel + fallback) under ``error`` filters must not
    raise."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    from repro.core.policy import policy_mm
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        with numerics.use(force=True, interpret=True, min_dim=0):
            policy_mm(a, b, "tcec_bf16x6")
        with numerics.use(enabled=False):
            policy_mm(a, b, "tcec_bf16x6")
