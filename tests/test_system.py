"""End-to-end behaviour tests for the paper's system: the accuracy claim
measured through a full train step, and the serving path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, device_batch
from repro.launch.step import make_train_step
from repro.models import get_model
from repro.optim import adamw


def _losses(policy, steps=6):
    cfg = get_smoke_config("qwen3-0.6b").replace(policy=policy)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    state = {"params": params, "opt": adamw.init_state(params, opt)}
    step = jax.jit(make_train_step(cfg, opt))
    data = DataConfig(seed=0, global_batch=4, seq_len=32)
    out = []
    for i in range(steps):
        state, m = step(state, device_batch(cfg, data, i))
        out.append(float(m["loss"]))
    return np.asarray(out)


def test_tcec_training_matches_fp32_end_to_end():
    """The paper's headline claim through optimizer dynamics: the corrected
    6-pass policy tracks fp32 loss far closer than uncorrected bf16."""
    ref = _losses("fp32")
    l6 = _losses("tcec_bf16x6")
    lb = _losses("bf16")
    d6 = float(np.max(np.abs(l6 - ref)))
    db = float(np.max(np.abs(lb - ref)))
    assert np.all(np.isfinite(ref)) and ref[-1] < ref[0]
    assert d6 < 1e-3, d6
    assert d6 < db + 1e-9, (d6, db)


def test_serving_generates_deterministically():
    from repro.launch.serve import generate
    cfg = get_smoke_config("mamba2-130m")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 4)),
        jnp.int32)
    a = generate(cfg, params, prompts, gen_len=6)
    b = generate(cfg, params, prompts, gen_len=6)
    assert a.shape == (2, 6)
    assert jnp.array_equal(a, b)
