"""Dispatch + autotuning subsystem tests.

Covers: dispatch-path selection (Pallas vs XLA fallback, escape hatch),
bit-equivalence of the two paths in interpret mode (forward AND the
policy-preserving backward), the batched grid and fused epilogue vs the
ref.py oracle, the autotuner cache round-trip (in-memory LRU, on-disk JSON,
cross-process reuse), and the models.layers epilogue-fusion hook.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import numerics
from repro.core.policy import POLICIES, get_policy, pdot, policy_bmm, policy_mm
from repro.kernels import (dispatch, tcec_bmm_ref, tcec_matmul,
                           tcec_matmul_ref, tuning)
from repro.numerics import NumericsConfig


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _bits(x):
    return np.asarray(x, dtype=np.float32).view(np.uint32)


# ----------------------------------------------------------- eligibility

def test_policy_eligibility_rules():
    assert dispatch.eligible_policy(get_policy("tcec_bf16x6"))
    assert dispatch.eligible_policy(get_policy("tcec_bf16x3"))
    assert not dispatch.eligible_policy(get_policy("fp32"))      # plain
    assert not dispatch.eligible_policy(get_policy("bf16"))      # plain
    assert not dispatch.eligible_policy(get_policy("fp16_halfhalf"))  # fp16
    assert not dispatch.eligible_policy(get_policy("fp16_markidis"))


def test_dispatch_off_by_default_on_cpu():
    """Without force, a CPU backend must keep the XLA term-expansion path."""
    a, b = _rand((128, 128), 0), _rand((128, 128), 1)
    pol = get_policy("tcec_bf16x6")
    dims = (((1,), (0,)), ((), ()))
    assert jax.default_backend() != "tpu"
    assert dispatch.maybe_dispatch(a, b, pol, dims) is None


def test_env_flags_treat_zero_as_off():
    for off in ("0", "false", "no", "off", ""):
        env = {"REPRO_FORCE_PALLAS": off, "REPRO_DISABLE_PALLAS": off}
        cfg = NumericsConfig.from_env(env)
        assert not cfg.force and cfg.enabled, off
    assert NumericsConfig.from_env({"REPRO_TUNE": "0"}).tune == "auto"
    with numerics.use(tune="auto"):
        assert tuning._should_measure() == (jax.default_backend() == "tpu")


def test_escape_hatch_env_var():
    cfg = NumericsConfig.from_env({"REPRO_DISABLE_PALLAS": "1"})
    assert not cfg.enabled
    # even under force, the hatch wins
    with numerics.use(enabled=False, force=True, min_dim=0,
                      interpret=True):
        a, b = _rand((128, 128), 0), _rand((128, 128), 1)
        out = dispatch.maybe_dispatch(a, b, get_policy("tcec_bf16x6"),
                                      (((1,), (0,)), ((), ())))
        assert out is None


def test_min_dim_gate_and_shape_rules():
    pol = get_policy("tcec_bf16x6")
    with numerics.use(force=True, interpret=True, min_dim=128):
        small = dispatch.maybe_dispatch(
            _rand((8, 32), 0), _rand((32, 16), 1), pol,
            (((1,), (0,)), ((), ())))
        assert small is None          # below min_dim -> XLA
    with numerics.use(force=True, interpret=True, min_dim=0):
        multi_m = dispatch.maybe_dispatch(
            _rand((4, 8, 128), 0), _rand((128, 128), 1), pol,
            (((2,), (0,)), ((), ())))
        assert multi_m is None        # a.ndim != nb+2 -> XLA


def test_explicit_cfg_argument_wins_over_ambient():
    """decide()/maybe_dispatch() take the config as an explicit static
    argument — the ambient context only supplies the default."""
    pol = get_policy("tcec_bf16x6")
    a, b = _rand((128, 128), 0), _rand((128, 128), 1)
    dims = (((1,), (0,)), ((), ()))
    on = numerics.active().replace(force=True, interpret=True, min_dim=0)
    off = on.replace(enabled=False)
    with numerics.use(enabled=False):
        assert dispatch.decide(a, b, pol, dims, cfg=on) is not None
    with numerics.use(force=True, interpret=True, min_dim=0):
        assert dispatch.decide(a, b, pol, dims, cfg=off) is None


# ------------------------------------------------------ bit-equivalence

def _xla(fn, *args):
    with numerics.use(enabled=False):
        return fn(*args)


def test_policy_mm_bit_identical_to_xla_path():
    """Acceptance: fused kernel == term expansion, bit for bit, when the
    K block covers the contraction (same RN-f32 operation sequence)."""
    a, b = _rand((256, 256), 2), _rand((256, 256), 3)
    for pol in ("tcec_bf16x3", "tcec_bf16x6"):
        with numerics.use(force=True, interpret=True, min_dim=0,
                               block=(256, 256, 256)):
            y_pal = policy_mm(a, b, pol)
        y_xla = _xla(policy_mm, a, b, pol)
        assert np.array_equal(_bits(y_pal), _bits(y_xla)), pol


def test_policy_bmm_bit_identical_to_xla_path():
    a, b = _rand((2, 128, 128), 4), _rand((2, 128, 128), 5)
    with numerics.use(force=True, interpret=True, min_dim=0,
                           block=(128, 128, 128)):
        y_pal = policy_bmm(a, b, "tcec_bf16x6")
    y_xla = _xla(policy_bmm, a, b, "tcec_bf16x6")
    assert np.array_equal(_bits(y_pal), _bits(y_xla))


def test_pdot_routes_through_kernel_and_matches():
    """pdot's canonical transpose makes attention/MLP-shaped einsums
    eligible; K-blocked dispatch stays allclose to the XLA path."""
    a, b = _rand((256, 384), 6), _rand((384, 128), 7)
    with numerics.use(force=True, interpret=True, min_dim=0):
        y_pal = pdot("mk,kn->mn", a, b, "tcec_bf16x6")
    y_xla = _xla(pdot, "mk,kn->mn", a, b, "tcec_bf16x6")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_xla),
                               rtol=1e-6, atol=1e-5)


def test_backward_is_policy_preserving_and_bit_identical():
    """The custom_vjp backward GEMMs (dA = g B^T, dB = A^T g) must also
    route through the kernel — and stay bit-identical with full-K blocks."""
    a = _rand((256, 256), 8)
    w = _rand((256, 256), 9)

    def loss(w):
        return jnp.sum(policy_mm(a, w, "tcec_bf16x6") ** 2)

    with numerics.use(force=True, interpret=True, min_dim=0,
                           block=(256, 256, 256)):
        g_pal = jax.grad(loss)(w)
    with numerics.use(enabled=False):
        g_xla = jax.grad(loss)(w)
    assert np.array_equal(_bits(g_pal), _bits(g_xla))


# ------------------------------------------- batched / epilogue kernels

def test_batched_kernel_vs_ref_oracle():
    a, b = _rand((3, 128, 256), 10), _rand((3, 256, 128), 11)
    out = tcec_matmul(a, b, policy="tcec_bf16x6", block=(128, 128, 128),
                      interpret=True)
    ref = tcec_bmm_ref(a, b, "tcec_bf16x6")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


def test_batched_kernel_nonaligned_pads():
    a, b = _rand((2, 100, 200), 12), _rand((2, 200, 60), 13)
    out = tcec_matmul(a, b, policy="tcec_bf16x3", block=(128, 128, 128),
                      interpret=True)
    assert out.shape == (2, 100, 60)
    ref = tcec_bmm_ref(a, b, "tcec_bf16x3")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu"])
def test_fused_epilogue_bitwise_vs_unfused(activation):
    from repro.kernels.ref import epilogue_ref
    a, b = _rand((128, 256), 14), _rand((256, 128), 15)
    bias = _rand((128,), 16)
    plain = tcec_matmul(a, b, policy="tcec_bf16x6", block=(128, 128, 128),
                        interpret=True)
    fused = tcec_matmul(a, b, policy="tcec_bf16x6", block=(128, 128, 128),
                        interpret=True, bias=bias, activation=activation,
                        out_scale=0.5)
    ref = epilogue_ref(plain, bias, activation, 0.5)
    if activation == "gelu":
        # gelu's tanh polynomial picks up different FMA contraction inside
        # vs outside the kernel graph — ULP-level, not algorithmic
        np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                                   rtol=2e-6, atol=2e-6)
    else:
        assert np.array_equal(_bits(fused), _bits(ref))


@pytest.mark.parametrize("activation", ["silu", "relu", None])
def test_fused_linear_layer_hook(activation):
    """models.layers.fused_linear: fused forward matches the unfused path,
    and its recompute-backward stays close to the unfused gradients — for
    every supported epilogue activation, not just the silu default."""
    from repro.models.layers import fused_linear
    x = _rand((2, 64, 128), 17)
    w = _rand((128, 256), 18)

    def run(fuse):
        kw = dict(fuse_epilogue=fuse, force=True, interpret=True, min_dim=0)
        with numerics.use(**kw):
            y, vjp = jax.vjp(
                lambda x, w: fused_linear(x, w, None, activation,
                                          "tcec_bf16x6"),
                x, w)
            dx, dw = vjp(jnp.ones_like(y))
        return y, dx, dw

    y_f, dx_f, dw_f = run(True)
    y_u, dx_u, dw_u = run(False)
    # regression (review finding): the custom_vjp must differentiate THIS
    # activation, not a silu default — oracle is plain autodiff through the
    # same policy forward (identical z bits, so identical relu mask)
    from repro.kernels.tcec_matmul import EPILOGUE_ACTIVATIONS

    def ref_loss(x, w):
        z = pdot("bsd,df->bsf", x, w, "tcec_bf16x6")
        return jnp.sum(EPILOGUE_ACTIVATIONS[activation](z))

    with numerics.use(fuse_epilogue=False, force=True, interpret=True,
                           min_dim=0):
        dx_ref, dw_ref = jax.grad(ref_loss, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(dx_f), np.asarray(dx_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw_f), np.asarray(dw_ref),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_u),
                               rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dx_f), np.asarray(dx_u),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw_f), np.asarray(dw_u),
                               rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------ autotuner

def test_autotune_cache_roundtrip(tmp_path):
    calls = []

    def fake_measure(block):
        calls.append(block)
        return 1.0 + abs(block[0] - 256) / 1e3   # prefers bm=256

    path = str(tmp_path / "tune.json")
    cache = tuning.BlockCache(path=path)
    blk1, meta1 = tuning.autotune(1, 512, 512, 512, "tcec_bf16x6",
                                  measure=fake_measure, cache=cache)
    assert meta1["source"] == "measured"
    assert blk1[0] == 256
    n_measured = len(calls)
    assert n_measured > 1

    # in-memory LRU hit: no re-measurement
    blk2, meta2 = tuning.autotune(1, 512, 512, 512, "tcec_bf16x6",
                                  measure=fake_measure, cache=cache)
    assert blk2 == blk1 and meta2["source"] == "cache"
    assert len(calls) == n_measured

    # shape bucketing: 500^3 pads to the same 512^3 bucket -> same entry
    blk3, meta3 = tuning.autotune(1, 500, 500, 500, "tcec_bf16x6",
                                  measure=fake_measure, cache=cache)
    assert blk3 == blk1 and meta3["source"] == "cache"

    # fresh cache object (new process) reads the persisted JSON: still no
    # re-measurement
    cache2 = tuning.BlockCache(path=path)
    blk4, meta4 = tuning.autotune(1, 512, 512, 512, "tcec_bf16x6",
                                  measure=fake_measure, cache=cache2)
    assert blk4 == blk1 and meta4["source"] == "cache"
    assert len(calls) == n_measured

    with open(path) as f:
        data = json.load(f)
    assert data["version"] == tuning.CACHE_VERSION
    [entry] = data["entries"].values()
    assert tuple(entry["block"]) == blk1 and entry["source"] == "measured"


def test_autotune_reuse_across_processes(tmp_path):
    """Acceptance: a *different process* reuses the persisted winner."""
    path = str(tmp_path / "tune.json")
    cache = tuning.BlockCache(path=path)
    blk, _ = tuning.autotune(1, 256, 256, 256, "tcec_bf16x3",
                             measure=lambda b: float(sum(b)), cache=cache)
    code = (
        "import json, sys\n"
        "sys.path.insert(0, 'src')\n"
        "from repro.kernels import tuning\n"
        f"cache = tuning.BlockCache(path={path!r})\n"
        "blk, meta = tuning.autotune(1, 256, 256, 256, 'tcec_bf16x3',\n"
        "    measure=lambda b: (_ for _ in ()).throw(AssertionError('remeasured')),\n"
        "    cache=cache)\n"
        "print('SOURCE', meta['source'], tuple(blk))\n"
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=300)
    assert f"SOURCE cache {blk}" in r.stdout, (r.stdout, r.stderr)


def test_heuristic_fallback_not_persisted(tmp_path):
    path = str(tmp_path / "tune.json")
    cache = tuning.BlockCache(path=path)
    with numerics.use(tune="off"):
        blk, meta = tuning.autotune(1, 1024, 1024, 1024, "tcec_bf16x6",
                                    cache=cache)
    assert meta["source"] == "heuristic"
    assert blk == tuning.heuristic_block(1024, 1024, 1024, "tcec_bf16x6")
    assert not (tmp_path / "tune.json").exists()   # heuristics never persist


def test_candidate_blocks_respect_vmem_and_alignment():
    for pol in POLICIES:
        if get_policy(pol).is_plain():
            continue
        for blk in tuning.candidate_blocks(4096, 4096, 4096, pol):
            assert all(s % 128 == 0 for s in blk)
        # no candidate overshoots a small padded problem
        for blk in tuning.candidate_blocks(128, 128, 128, pol):
            assert blk == (128, 128, 128)
