"""Policy-conformance battery: registering a policy IS testing it.

Every entry in ``repro.core.POLICIES`` runs through one shared contract —
schedule invariants, split round-trips, fold-order discipline, forward/
backward policy agreement through ``custom_vjp``, accuracy ordering vs the
f32/f64 oracles, dispatch/fallback parity where fused-eligible, and
measured error within the ``core/theory.py`` closed-form bound.  The
checks are plain functions over ``PrecisionPolicy`` objects so the
meta-tests can hand them deliberately-broken unregistered policies and
assert the battery rejects them.

Runs under ``python -O`` in CI: every contract violation raises a typed
error or goes through ``_require`` (never a bare ``assert`` for input
validation paths like ``pdot`` subscript parsing, which has its own
``-O`` subprocess test here).
"""
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import numerics
from repro.core import POLICIES, get_policy, pdot, policy_mm, split, theory
from repro.core.matgen import exp_rand, relative_residual, urand
from repro.core.policy import (EinsumParseError, PrecisionPolicy, _dot_impl,
                               _tcec_dot, full_keep, tcec_dot_unevaluated,
                               triangular_keep)
from repro.core.split import MANTISSA_BITS
from repro.kernels import dispatch, tuning
from repro.obs import numerics_health

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The literal registry: the registry-completeness lint (ci.yml) greps each
# name here, in docs/numerics.md, and in benchmarks/fig11_exponent_range.py.
# Registering a policy without threading it through all three fails CI.
EXPECTED_POLICIES = [
    "bf16",
    "fp16_halfhalf",
    "fp16_markidis",
    "fp32",
    "tcec_bf16x10",
    "tcec_bf16x3",
    "tcec_bf16x6",
    "tcec_bf16x9",
    "tcec_fp8e4m3x10",
    "tcec_fp8e4m3x6",
    "tcec_fp8e5m2x6",
]

ALL = sorted(POLICIES)
SPLIT_POLICIES = [n for n in ALL if not POLICIES[n].is_plain()]


def test_registry_is_the_expected_literal():
    """Keeps EXPECTED_POLICIES greppable and exhaustive: growing POLICIES
    without updating the literal (and hence docs + fig11) fails here."""
    assert EXPECTED_POLICIES == ALL


# --------------------------------------------------------------- helpers

def _require(cond, msg):
    """Battery assertion that survives ``python -O``."""
    if not cond:
        raise AssertionError(msg)


def operand_band(pol: PrecisionPolicy) -> tuple[int, int]:
    """Unbiased-exponent generator band for one policy: the theory safe
    range where non-empty, else the format's representable band (fp8_e4m3,
    whose gradual-underflow floor the error bound carries), clamped so
    K-deep f32 products stay finite."""
    if pol.is_plain():
        if pol.name == "fp32":
            return (-30, 14)
        fmt = theory.FORMATS_BY_DTYPE[pol.dtype]
        lo, hi = theory.representable_range(fmt)
    else:
        fmt = theory.FORMATS_BY_DTYPE[pol.dtype]
        lo, hi = theory.safe_exponent_range(fmt, pol.scale_bits)
        if lo > hi:  # strict band empty (fp8_e4m3)
            lo, hi = theory.representable_range(fmt)
    return max(lo, -40), min(hi, 14)


def _band_mats(pol, m, k, n, seed):
    lo, hi = operand_band(pol)
    a = exp_rand((m, k), lo, hi, seed=seed)
    b = exp_rand((k, n), lo, hi, seed=seed + 1)
    return a, b


def _residual(pol, a, b):
    # _dot_impl (not policy_mm) so unregistered dummy policies from the
    # meta-tests run the identical forward path without a registry entry
    c = _dot_impl(jnp.asarray(a), jnp.asarray(b), pol,
                  (((1,), (0,)), ((), ())))
    return relative_residual(np.asarray(c), a, b)


# ------------------------------------------------ battery check functions
#
# Each takes a PrecisionPolicy (registered or not) and raises on violation
# — the parametrized tests below drive them over POLICIES; the meta-tests
# drive them over deliberately-broken dummies.

def check_schedule(pol: PrecisionPolicy):
    """Term-schedule / scale-group invariants."""
    if pol.is_plain():
        _require(pol.keep == (), f"{pol.name}: plain policies keep nothing")
        return
    _require(pol.jdtype in MANTISSA_BITS,
             f"{pol.name}: no mantissa table entry for {pol.dtype}")
    _require(len(set(pol.keep)) == len(pol.keep),
             f"{pol.name}: duplicate keep entries double-count products")
    for (i, j) in pol.keep:
        _require(0 <= i < pol.n_splits and 0 <= j < pol.n_splits,
                 f"{pol.name}: keep ({i},{j}) outside the "
                 f"{pol.n_splits}-way split")
    _require((0, 0) in pol.keep,
             f"{pol.name}: the leading product (0,0) must be kept")
    _require(pol.groups == tuple(sorted({i + j for (i, j) in pol.keep})),
             f"{pol.name}: groups property inconsistent with keep")
    _require(pol.passes == len(pol.keep), f"{pol.name}: passes != |keep|")
    _require(set(pol.keep) >= set(triangular_keep(2)) or pol.n_splits < 2,
             f"{pol.name}: first-order correction terms missing")
    _require(pol.scale_bits >= 0, f"{pol.name}: negative scale shift")


def check_split_roundtrip(pol: PrecisionPolicy, seed: int = 0):
    """sum(split(x)) == x bitwise where x is exactly representable in the
    first term; residual within the closed-form bound otherwise."""
    if pol.is_plain():
        return
    x = jnp.asarray(urand((512,), seed=seed))
    exact = x.astype(pol.jdtype).astype(jnp.float32)
    parts = split(exact, pol.jdtype, pol.n_splits, pol.scale_bits)
    rec = sum(p.astype(jnp.float32) * jnp.float32(2.0 ** (-i * pol.scale_bits))
              for i, p in enumerate(parts))
    _require(bool(jnp.array_equal(rec, exact)),
             f"{pol.name}: representable values must round-trip bitwise")

    lo, hi = operand_band(pol)
    y = np.asarray(exp_rand((2048,), lo, hi, seed=seed + 1))
    parts = split(jnp.asarray(y), pol.jdtype, pol.n_splits, pol.scale_bits)
    rec = np.zeros_like(y, dtype=np.float64)
    for i, p in enumerate(parts):
        rec += np.asarray(p.astype(jnp.float32), np.float64) \
            * 2.0 ** (-i * pol.scale_bits)
    fmt = theory.FORMATS_BY_DTYPE[pol.dtype]
    e = np.floor(np.log2(np.abs(y))).astype(int)
    bound = np.array([theory.split_residual_bound(
        fmt, pol.n_splits, pol.scale_bits, e_lo=int(ei)) for ei in e])
    rel = np.abs(rec - y.astype(np.float64)) / np.abs(y)
    bad = rel > 2.0 * bound
    _require(not bad.any(),
             f"{pol.name}: split residual {rel[bad][:3]} above closed-form "
             f"bound {bound[bad][:3]} at exponents {e[bad][:3]}")


def check_error_bound(pol: PrecisionPolicy, m=64, k=256, n=64, seed=11):
    """Measured Eq. (7) residual within theory.policy_error_bound."""
    a, b = _band_mats(pol, m, k, n, seed)
    res = _residual(pol, a, b)
    lo, _ = operand_band(pol)
    bound = theory.policy_error_bound(pol, k, e_lo=lo)
    _require(np.isfinite(res),
             f"{pol.name}: non-finite residual on in-band operands")
    _require(res <= bound,
             f"{pol.name}: residual {res:.3e} above closed-form bound "
             f"{bound:.3e}")


def check_fold_order(pol: PrecisionPolicy, seed=5):
    """The epilogue must fold scale groups smallest-first; the battery
    recomputes the fold both ways and requires the implementation to match
    the smallest-first reference bitwise (and, where the schedule has >1
    group and the largest-first fold differs, to differ from it)."""
    if pol.is_plain() or pol.compensated:
        return
    lo, hi = operand_band(pol)
    a = jnp.asarray(exp_rand((32, 64), lo, hi, seed=seed))
    b = jnp.asarray(exp_rand((64, 32), lo, hi, seed=seed + 1))
    dims = (((1,), (0,)), ((), ()))
    with numerics.use(enabled=False):
        cfg = numerics.active()
        out = _tcec_dot(a, b, pol, dims, cfg)
        sa = split(a, pol.jdtype, pol.n_splits, pol.scale_bits)
        sb = split(b, pol.jdtype, pol.n_splits, pol.scale_bits)
        groups = {}
        for (i, j) in pol.keep:
            x, y = sa[i].astype(jnp.float32), sb[j].astype(jnp.float32)
            t = jax.lax.dot_general(x, y, dims,
                                    preferred_element_type=jnp.float32)
            g = i + j
            groups[g] = t if g not in groups else groups[g] + t
    small_first, big_first = None, None
    for g in sorted(groups, reverse=True):
        t = groups[g] * jnp.float32(2.0 ** (-g * pol.scale_bits))
        small_first = t if small_first is None else small_first + t
    for g in sorted(groups):
        t = groups[g] * jnp.float32(2.0 ** (-g * pol.scale_bits))
        big_first = t if big_first is None else big_first + t
    _require(bool(jnp.array_equal(out, small_first)),
             f"{pol.name}: epilogue is not the smallest-first fold")
    if len(groups) > 1 and not bool(jnp.array_equal(small_first, big_first)):
        _require(not bool(jnp.array_equal(out, big_first)),
                 f"{pol.name}: epilogue matched the largest-first fold")


def check_fwd_bwd_agreement(pol: PrecisionPolicy, seed=7):
    """custom_vjp backward GEMMs run under the same policy: grad of
    sum(A @ B) must equal the policy dot of ones @ B^T bitwise."""
    lo, hi = operand_band(pol)
    a = jnp.asarray(exp_rand((16, 32), lo, hi, seed=seed))
    b = jnp.asarray(exp_rand((32, 8), lo, hi, seed=seed + 1))
    da = jax.grad(lambda x: jnp.sum(policy_mm(x, b, pol)))(a)
    ones = jnp.ones((16, 8), jnp.float32)
    expected = _dot_impl(ones, b, pol, (((1,), (1,)), ((), ())))
    _require(bool(jnp.array_equal(da, expected)),
             f"{pol.name}: backward GEMM did not run under the policy")


def check_oracle_ordering(pol: PrecisionPolicy, seed=13):
    """Accuracy ordering vs the f32 / f64 oracles: any split policy beats
    its plain storage-dtype baseline by a wide margin on in-band operands,
    and no policy beats the f64 oracle (residuals are well-defined)."""
    if pol.is_plain():
        return
    a, b = _band_mats(pol, 48, 192, 48, seed)
    res = _residual(pol, a, b)
    plain = PrecisionPolicy(name=f"_plain_{pol.dtype}", dtype=pol.dtype)
    with numerics.use(enabled=False):
        cfg = numerics.active()
        from repro.core.policy import _plain_dot
        c = _plain_dot(jnp.asarray(a), jnp.asarray(b), plain,
                       (((1,), (0,)), ((), ())), cfg)
    res_plain = relative_residual(np.asarray(c), a, b)
    _require(res < res_plain / 4,
             f"{pol.name}: split residual {res:.3e} does not beat plain "
             f"{pol.dtype} {res_plain:.3e}")


def check_dispatch(pol: PrecisionPolicy, seed=17):
    """Fused-kernel routing: eligible policies dispatch (interpret mode)
    and match the XLA term-expansion fallback; ineligible split policies
    decline cleanly (maybe_dispatch -> None -> fallback), and all paths
    agree with the f64 oracle to the policy bound."""
    a, b = _band_mats(pol, 128, 128, 128, seed)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    dims = (((1,), (0,)), ((), ()))
    with numerics.use(force=True, interpret=True, min_dim=0, tune="off"):
        cfg = numerics.active()
        fused = dispatch.maybe_dispatch(aj, bj, pol, dims, cfg)
    if dispatch.eligible_policy(pol):
        _require(fused is not None,
                 f"{pol.name}: eligible policy failed to dispatch")
        with numerics.use(enabled=False):
            fallback = _tcec_dot(aj, bj, pol, dims, numerics.active())
        err = float(jnp.max(jnp.abs(fused - fallback)))
        scale = float(jnp.max(jnp.abs(fallback))) + 1e-30
        _require(err <= 1e-6 * scale,
                 f"{pol.name}: fused kernel diverges from XLA fallback "
                 f"({err:.3e} vs scale {scale:.3e})")
    else:
        _require(fused is None,
                 f"{pol.name}: ineligible policy must decline dispatch")


# ------------------------------------------------- parametrized battery

@pytest.mark.parametrize("name", ALL)
def test_schedule_invariants(name):
    check_schedule(POLICIES[name])


@pytest.mark.parametrize("name", ALL)
def test_split_roundtrip(name):
    check_split_roundtrip(POLICIES[name])


@pytest.mark.parametrize("name", ALL)
def test_error_within_theory_bound(name):
    check_error_bound(POLICIES[name])


@pytest.mark.parametrize("name", ALL)
def test_epilogue_fold_order(name):
    check_fold_order(POLICIES[name])


@pytest.mark.parametrize("name", ALL)
def test_forward_backward_policy_agreement(name):
    check_fwd_bwd_agreement(POLICIES[name])


@pytest.mark.parametrize("name", ALL)
def test_accuracy_vs_oracles(name):
    check_oracle_ordering(POLICIES[name])


@pytest.mark.parametrize("name", ALL)
def test_dispatch_or_clean_decline(name):
    check_dispatch(POLICIES[name])


def test_tuning_cache_keys_distinct_per_policy():
    keys = {tuning.cache_key(1, 256, 256, 256, n, "cpu") for n in ALL}
    assert len(keys) == len(ALL)


# ------------------------------------------- property-based generators
#
# Replaces hand-picked shapes/exponent cases: shapes and exponent bands are
# drawn per example; every draw checks a random policy against its bound.

@given(st.sampled_from(SPLIT_POLICIES), st.integers(0, 10**6),
       st.integers(1, 6), st.integers(1, 8), st.integers(1, 6))
@settings(max_examples=12, deadline=None)
def test_property_shapes_and_bands(name, seed, mq, kq, nq):
    pol = POLICIES[name]
    m, k, n = 8 * mq, 32 * kq, 8 * nq
    a, b = _band_mats(pol, m, k, n, seed % 100_000)
    res = _residual(pol, a, b)
    lo, _ = operand_band(pol)
    bound = theory.policy_error_bound(pol, k, e_lo=lo)
    assert np.isfinite(res) and res <= bound, (name, m, k, n, res, bound)


@given(st.sampled_from(SPLIT_POLICIES), st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_property_split_roundtrip(name, seed):
    check_split_roundtrip(POLICIES[name], seed=seed % 100_000)


# ------------------------------------------------- multi-term headliners

def test_multiterm_f64_grade_unevaluated_sum():
    """tcec_bf16x9's compensated unevaluated pair carries f64-grade
    accuracy (~2^-48) — the Chen/Verschelde multi-double regime."""
    a = urand((64, 256), seed=31)
    b = urand((256, 64), seed=32)
    h, t = tcec_dot_unevaluated(jnp.asarray(a), jnp.asarray(b), "tcec_bf16x9")
    ref = a.astype(np.float64) @ b.astype(np.float64)
    val = np.asarray(h, np.float64) + np.asarray(t, np.float64)
    rel = np.linalg.norm(val - ref) / np.linalg.norm(ref)
    assert rel < 1e-13, rel
    # the folded f32 head alone is the correctly-rounded f32 GEMM
    rel_head = relative_residual(np.asarray(h), a, b)
    assert rel_head < 6e-8, rel_head


def test_multiterm_strictly_beats_x6_on_fig11_types():
    """Acceptance pin: tcec_bf16x9 strictly below tcec_bf16x6 on every
    fig11 exponent-range type (compensation removes the f32 accumulation
    noise that floors x6)."""
    bands = {"Type1": ((-15, 14), (-15, 14)),
             "Type2": ((-15, 14), (-100, -35)),
             "Type3": ((-35, -15), (-35, -15)),
             "Type4": ((-100, -35), (-100, -35))}
    for ti, (tname, ((alo, ahi), (blo, bhi))) in enumerate(bands.items()):
        a = exp_rand((128, 128), alo, ahi, seed=100 + 2 * ti)
        b = exp_rand((128, 128), blo, bhi, seed=101 + 2 * ti)
        r9 = _residual(POLICIES["tcec_bf16x9"], a, b)
        r6 = _residual(POLICIES["tcec_bf16x6"], a, b)
        assert r9 < r6, (tname, r9, r6)
        assert r9 < 0.5 * r6, (tname, r9, r6)


def test_multiterm_keep_schedules_are_programmatic():
    assert set(POLICIES["tcec_bf16x3"].keep) == set(triangular_keep(2))
    assert set(POLICIES["tcec_bf16x6"].keep) == set(triangular_keep(3))
    assert POLICIES["tcec_bf16x10"].keep == triangular_keep(4)
    assert POLICIES["tcec_bf16x9"].keep == full_keep(3)
    assert len(triangular_keep(4)) == 10 and len(full_keep(3)) == 9


def test_multiterm_x10_rides_the_parametric_kernel():
    """The 4-way schedule reaches the fused kernel unchanged: 4 scale
    groups, 10 passes, fused/fallback parity (check_dispatch covers the
    numbers; this pins the structural claim)."""
    pol = POLICIES["tcec_bf16x10"]
    assert dispatch.eligible_policy(pol)
    assert pol.groups == (0, 1, 2, 3) and pol.passes == 10


# ------------------------------------------------------- fp8 pins

def test_fp8_policies_decline_dispatch_and_upcast():
    for name in ("tcec_fp8e4m3x6", "tcec_fp8e4m3x10", "tcec_fp8e5m2x6"):
        pol = POLICIES[name]
        assert pol.upcast_products and not dispatch.eligible_policy(pol)


def test_fp8_safe_ranges_pinned():
    """theory.safe_exponent_range per storage format (satellite pin):
    e4m3's strict zero-underflow band is empty — its 4-bit exponent cannot
    escape gradual underflow at any operand exponent — while e5m2's wider
    exponent buys a real band."""
    lo, hi = numerics_health.safe_exponent_range("float8_e4m3fn", 4)
    assert lo > hi
    assert numerics_health.safe_exponent_range("float8_e5m2", 3) == (7, 15)
    # existing pins must not move
    assert numerics_health.safe_exponent_range("bfloat16", 8) == (-110, 127)
    assert numerics_health.safe_exponent_range("float16", 11) == (-1, 15)
    assert numerics_health.safe_exponent_range("float16", 0) == (10, 26)
    # multi-term bf16 shares the bf16 band
    p10 = POLICIES["tcec_bf16x10"]
    assert numerics_health.safe_exponent_range(p10.dtype,
                                               p10.scale_bits) == (-110, 127)


def test_fp8_out_of_band_degrades_not_silently():
    """Outside its representable band e4m3 storage saturates (fn: to NaN)
    — out-of-band operands must not come back looking plausible."""
    a = exp_rand((32, 32), 9, 12, seed=3)   # above e4m3's max exponent
    b = exp_rand((32, 32), 9, 12, seed=4)
    c = np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b),
                             "tcec_fp8e4m3x6"))
    assert not np.isfinite(c).all()


def test_exponent_band_sweep_per_policy():
    """fig11-as-a-test (satellite): inside each policy's band the measured
    residual respects the closed-form bound; K swept across bands."""
    for name in SPLIT_POLICIES:
        pol = POLICIES[name]
        for k in (64, 256):
            check_error_bound(pol, m=32, k=k, n=32, seed=19 + k)


# ------------------------------------------------------- meta-tests
#
# A deliberately-broken policy must FAIL the battery — this is what makes
# "registering a policy is testing it" trustworthy.  The checks are run
# as one battery: different sabotage trips different checks, and a policy
# is conformant only when every check passes.

BATTERY = [check_schedule, check_split_roundtrip, check_error_bound,
           check_fold_order, check_oracle_ordering]


def _battery_failures(pol: PrecisionPolicy) -> list[str]:
    fails = []
    for chk in BATTERY:
        try:
            chk(pol)
        except Exception:  # any raise is a conformance failure — a broken
            fails.append(chk.__name__)  # schedule can crash term expansion
    return fails


def test_meta_broken_schedule_fails():
    bad = PrecisionPolicy(name="broken_idx", dtype="bfloat16", n_splits=3,
                          scale_bits=8, keep=((0, 0), (0, 1), (1, 0), (3, 0)))
    assert "check_schedule" in _battery_failures(bad)
    dup = PrecisionPolicy(name="broken_dup", dtype="bfloat16", n_splits=2,
                          scale_bits=8, keep=((0, 0), (0, 1), (0, 1)))
    assert "check_schedule" in _battery_failures(dup)


def test_meta_broken_correction_fails_battery():
    """Dropping the first-order correction terms leaves ~2^-8 of error —
    the split buys nothing over plain bf16, so the oracle-ordering check
    rejects it (and the schedule check flags the missing terms)."""
    bad = PrecisionPolicy(name="broken_nocorr", dtype="bfloat16", n_splits=3,
                          scale_bits=8,
                          keep=((0, 0), (1, 1), (0, 2), (2, 0)))
    fails = _battery_failures(bad)
    assert "check_schedule" in fails
    assert "check_oracle_ordering" in fails


def test_meta_healthy_dummy_passes():
    """Sanity: an unregistered but *correct* policy passes every check the
    broken ones fail (the battery measures the policy, not the name)."""
    ok = PrecisionPolicy(name="dummy_x6", dtype="bfloat16", n_splits=3,
                         scale_bits=8, keep=triangular_keep(3))
    assert _battery_failures(ok) == []


# ------------------------------------------------------- -O safety

def test_parse_error_is_typed():
    for bad in ("ij,jk", "ij,jk,kl->il", "ii,ij->ij", "ij,jk->iq"):
        with pytest.raises(EinsumParseError):
            pdot(bad, jnp.ones((2, 2)), jnp.ones((2, 2)), "fp32")


def test_parse_error_survives_python_O():
    """Satellite pin: malformed pdot subscripts raise the typed error even
    under ``python -O`` (a bare assert would be stripped and silently
    mis-contract)."""
    code = (
        "import jax.numpy as jnp\n"
        "from repro.core import pdot\n"
        "from repro.core.policy import EinsumParseError\n"
        "try:\n"
        "    pdot('ij,jk->iq', jnp.ones((2, 2)), jnp.ones((2, 2)), 'fp32')\n"
        "except EinsumParseError:\n"
        "    print('TYPED-ERROR-OK')\n"
        "else:\n"
        "    raise SystemExit('no error raised')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr
    assert "TYPED-ERROR-OK" in out.stdout


# ------------------------------------------------ registry completeness

def _read(rel):
    with open(os.path.join(REPO, rel)) as f:
        return f.read()


def test_every_policy_documented_in_numerics_md():
    doc = _read("docs/numerics.md")
    for name in ALL:
        assert f"`{name}`" in doc, f"{name} missing from docs/numerics.md"


def test_every_policy_in_fig11_bench():
    src = _read("benchmarks/fig11_exponent_range.py")
    for name in ALL:
        assert f'"{name}"' in src, f"{name} missing from fig11 METHODS"
