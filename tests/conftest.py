"""Test-suite bootstrap.

Ensures ``src/`` is importable (so a bare ``pytest`` works without
``PYTHONPATH=src``) and installs a deterministic fallback for
``hypothesis`` when the real package is absent.  The project declares
``hypothesis`` as a dev dependency in ``pyproject.toml``; the fallback
exists so the tier-1 suite still *runs* (with a fixed, smaller example
set) in minimal containers where installing extras is not possible.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401  (the real thing wins when installed)
except ImportError:
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
