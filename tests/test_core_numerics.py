"""Unit + property tests for the core TCEC numerics (paper Eqs. 2-24)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import POLICIES, get_policy, pdot, policy_mm, split, reconstruct
from repro.core.matgen import exp_rand, relative_residual, urand
from repro.core import theory


# ---------------------------------------------------------------- splitting

def test_split_reconstruct_bf16x3_is_fp32_exact_to_24_bits():
    x = jnp.asarray(urand((1024,), seed=0))
    parts = split(x, jnp.bfloat16, 3, 8)
    rec = reconstruct(parts, 8)
    # 3x8 = 24 mantissa bits >= fp32's 24 -> reconstruction is (near-)exact
    assert float(jnp.max(jnp.abs(rec - x))) <= float(jnp.max(jnp.abs(x))) * 2**-22


def test_split_residual_scaling_is_exponent_only():
    x = jnp.asarray(urand((512,), seed=1))
    lo_scaled = split(x, jnp.bfloat16, 2, 8)[1].astype(jnp.float32) * 2.0**-8
    lo_plain = split(x, jnp.bfloat16, 2, 0)[1].astype(jnp.float32)
    # away from the subnormal band, scaling must not change the value kept
    np.testing.assert_allclose(np.asarray(lo_scaled), np.asarray(lo_plain),
                               rtol=0, atol=0)


def test_scaling_rescues_gradual_underflow_fp16():
    # values ~2^-9: residual exponent ~2^-20 < fp16 normal min 2^-14
    x = jnp.asarray(exp_rand((4096,), -9, -9, seed=2))
    lo_plain = split(x, jnp.float16, 2, 0)[1]
    lo_scaled = split(x, jnp.float16, 2, 11)[1]
    rec_plain = reconstruct(split(x, jnp.float16, 2, 0), 0)
    rec_scaled = reconstruct(split(x, jnp.float16, 2, 11), 11)
    err_plain = float(jnp.max(jnp.abs(rec_plain - x) / jnp.abs(x)))
    err_scaled = float(jnp.max(jnp.abs(rec_scaled - x) / jnp.abs(x)))
    assert err_scaled < err_plain
    assert err_scaled < 2**-21


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_split_reconstruct_property_random_seed(seed):
    x = jnp.asarray(urand((64,), seed=seed))
    for pol_name in ("tcec_bf16x3", "tcec_bf16x6"):
        pol = get_policy(pol_name)
        rec = reconstruct(split(x, pol.jdtype, pol.n_splits, pol.scale_bits),
                          pol.scale_bits)
        bits = 8 * pol.n_splits
        tol = 2.0 ** -(min(bits, 24) - 1)
        assert float(jnp.max(jnp.abs(rec - x))) <= tol


# ------------------------------------------------------------------- theory

def test_expected_mantissa_length_matches_paper_table1():
    assert theory.expected_mantissa_length(10, "rn") == pytest.approx(22.75)


def test_expected_mantissa_length_rz_matches_paper_table2_rows():
    # Paper text says 22.5 but Table 2's own rows give
    # 23*(1/2) + 22*(1/4) + 21*(1/4) = 22.25; exact enumeration agrees with
    # the table (we record the text/table discrepancy in EXPERIMENTS.md).
    assert theory.expected_mantissa_length(10, "rz") == pytest.approx(22.25)


def test_underflow_theory_matches_monte_carlo_fp16():
    for e_v in (0, -3, 3):
        p_theory = theory.p_underflow_gradual(e_v, theory.FP16)
        _, p_meas = theory.measure_underflow(e_v, theory.FP16, n=200_000)
        assert p_meas == pytest.approx(p_theory, abs=3e-3)


def test_scaling_eliminates_underflow_fp16():
    assert theory.p_underflow_gradual(0, theory.FP16, scale_bits=11) == 0
    u, gu = theory.measure_underflow(0, theory.FP16, scale_bits=11, n=50_000)
    assert u == 0 and gu == 0


def test_bf16_has_no_underflow_at_moderate_exponents():
    # the tf32-analogue claim: bf16's 8-bit exponent covers the fp32 range
    for e_v in range(-100, 100, 20):
        assert theory.p_underflow_gradual(e_v, theory.BF16, scale_bits=8) == 0


# ----------------------------------------------------------- GEMM accuracy

ACCURACY_ORDER = ["bf16", "tcec_bf16x3", "fp32"]


def test_policy_accuracy_ordering():
    a = urand((256, 512), seed=3)
    b = urand((512, 256), seed=4)
    res = {p: relative_residual(
        np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), p)), a, b)
        for p in POLICIES}
    # Fig. 1 ordering: plain bf16 ≫ x3 > fp32 ≈ halfhalf ≈ x6
    assert res["bf16"] > 100 * res["tcec_bf16x3"]
    assert res["tcec_bf16x3"] > res["fp32"]
    assert res["tcec_bf16x6"] <= 2 * res["fp32"]
    assert res["fp16_halfhalf"] <= 2 * res["fp32"]
    assert res["fp16_markidis"] <= 4 * res["fp32"]


def test_tcec_bf16x6_matches_fp32_accuracy_across_k():
    # Fig. 1: the corrected method tracks SGEMM accuracy as k grows
    for k in (64, 256, 1024):
        a = urand((16, k), seed=k)
        b = urand((k, 16), seed=k + 1)
        r6 = relative_residual(
            np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), "tcec_bf16x6")), a, b)
        r32 = relative_residual(
            np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), "fp32")), a, b)
        assert r6 <= 2.0 * r32 + 1e-9


def test_exponent_range_types_fig11():
    """bf16 policies cover all Fig.-11 input types (the tf32tf32 claim)."""
    t1 = exp_rand((64, 64), -15, 14, seed=5)
    t3 = exp_rand((64, 64), -35, -15, seed=6)
    for inputs in [(t1, t1), (t3, t3)]:
        a, b = inputs
        r = relative_residual(
            np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), "tcec_bf16x6")), a, b)
        r32 = relative_residual(
            np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), "fp32")), a, b)
        assert r <= 4 * r32 + 1e-9
    # fp16 halfhalf loses Type-3 (paper Fig. 11) while bf16 does not
    r_fp16 = relative_residual(
        np.asarray(policy_mm(jnp.asarray(t3), jnp.asarray(t3), "fp16_halfhalf")), t3, t3)
    r_bf16 = relative_residual(
        np.asarray(policy_mm(jnp.asarray(t3), jnp.asarray(t3), "tcec_bf16x6")), t3, t3)
    assert r_bf16 < r_fp16


# ------------------------------------------------------------------- pdot

def test_pdot_matches_einsum_fp32():
    rng = np.random.default_rng(0)
    cases = [
        ("mk,kn->mn", (32, 48), (48, 16)),
        ("bshd,hdD->bsD", (2, 16, 4, 8), (4, 8, 24)),
        ("bhqd,bhkd->bhqk", (2, 4, 8, 16), (2, 4, 12, 16)),
        ("bhqk,bhkd->bhqd", (2, 4, 8, 12), (2, 4, 12, 16)),
        ("ebcd,edf->ebcf", (3, 2, 5, 8), (3, 8, 7)),
        ("bsD,DV->bsV", (2, 16, 8), (8, 32)),
    ]
    for sub, sa, sb in cases:
        a = jnp.asarray(rng.standard_normal(sa).astype(np.float32))
        b = jnp.asarray(rng.standard_normal(sb).astype(np.float32))
        out = pdot(sub, a, b, "fp32")
        ref = jnp.einsum(sub, a, b, precision="highest")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-6, atol=2e-6)


def test_pdot_gradients_match_fp32_reference():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))

    def mk_loss(pol):
        return lambda w: jnp.sum(pdot("mk,kn->mn", a, w, pol) ** 2)

    g6 = jax.grad(mk_loss("tcec_bf16x6"))(w)
    g32 = jax.grad(mk_loss("fp32"))(w)
    np.testing.assert_allclose(np.asarray(g6), np.asarray(g32),
                               rtol=5e-3, atol=5e-3)
    # x6 backward must itself be split-accurate, not a bf16 fallback
    gbf = jax.grad(mk_loss("bf16"))(w)
    err6 = float(jnp.max(jnp.abs(g6 - g32)))
    errbf = float(jnp.max(jnp.abs(gbf - g32)))
    assert err6 < errbf / 10


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=30, deadline=None)
def test_tcec_linearity_property(seed):
    """GEMM emulation must be exactly linear in exponent scaling:
    (2^t A) @ B == 2^t (A @ B) bit-for-bit (exponent-only transforms)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    t = int(rng.integers(-8, 9))
    lhs = policy_mm(a * 2.0**t, b, "tcec_bf16x6")
    rhs = policy_mm(a, b, "tcec_bf16x6") * 2.0**t
    assert jnp.array_equal(lhs, rhs)


def test_mma_rz_reproduces_markidis_error_fig5():
    """The paper's smoking gun: RZ accumulation degrades the corrected GEMM,
    RN accumulation matches SGEMM."""
    from repro.core.accum import markidis_gemm_sim
    k = 4096
    a = urand((16, k), seed=7)
    b = urand((k, 16), seed=8)
    r_rn = relative_residual(markidis_gemm_sim(a, b, "rn"), a, b)
    r_rz = relative_residual(markidis_gemm_sim(a, b, "rz"), a, b)
    r_32 = relative_residual(
        np.asarray(policy_mm(jnp.asarray(a), jnp.asarray(b), "fp32")), a, b)
    assert r_rn <= 3 * r_32          # RN simulator ~= SGEMM
    assert r_rz > 5 * r_rn           # RZ visibly worse (Markidis' curve)
