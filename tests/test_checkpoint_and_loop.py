"""Fault-tolerance tests: checkpoint atomicity/integrity/retention, resume,
deterministic data replay, straggler watchdog, and optimizer behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, host_batch
from repro.optim import adamw
from repro.train.loop import StragglerEvent, TrainLoopConfig, train


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path)
    t = _tree()
    for s in (10, 20, 30, 40):
        ckpt.save(d, s, t)
    assert ckpt.latest_step(d) == 40
    ckpt.retain(d, keep=2)
    assert sorted(int(x.split("_")[1]) for x in os.listdir(d)) == [30, 40]
    like = jax.eval_shape(lambda: _tree())
    restored = ckpt.restore(d, 40, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path)
    t = _tree()
    path = ckpt.save(d, 5, t)
    victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, victim))
    arr = np.asarray(arr).copy()
    flat = arr.reshape(-1).view(np.uint8)
    flat[0] ^= 0xFF
    np.save(os.path.join(path, victim), arr)
    like = jax.eval_shape(lambda: _tree())
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(d, 5, like)


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    # a stale .tmp dir (simulated crash) must be ignored by latest_step
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


def test_data_pipeline_deterministic_and_sharded():
    cfg = get_smoke_config("qwen3-0.6b")
    dc = DataConfig(seed=3, global_batch=8, seq_len=16)
    a = host_batch(cfg, dc, step=5)
    b = host_batch(cfg, dc, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = host_batch(cfg, dc, step=6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions the global batch
    h0 = host_batch(cfg, dc, step=5, host_index=0, num_hosts=2)
    assert h0["tokens"].shape[0] == 4


def test_train_loop_runs_resumes_and_replays(tmp_path):
    cfg = get_smoke_config("qwen3-0.6b")
    opt = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=12)
    data = DataConfig(seed=0, global_batch=4, seq_len=16)
    d = str(tmp_path)

    loop1 = TrainLoopConfig(total_steps=6, ckpt_every=3,
                            straggler_factor=1e9)
    state1, hist1 = train(cfg, opt, data, loop1, d, log=lambda *_: None)
    assert ckpt.latest_step(d) == 6
    losses1 = [h["loss"] for h in hist1]
    assert all(np.isfinite(losses1))
    assert losses1[-1] < losses1[0]          # it learns

    # run to 12 in one go vs resume-from-6: identical final params
    loop2 = TrainLoopConfig(total_steps=12, ckpt_every=6,
                            straggler_factor=1e9)
    state_resumed, _ = train(cfg, opt, data, loop2, d, log=lambda *_: None)
    d2 = str(tmp_path / "fresh")
    state_fresh, _ = train(cfg, opt, data, loop2, d2, log=lambda *_: None)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state_resumed["params"], state_fresh["params"])))
    assert diff < 1e-5, diff                  # bit-replayable restart


def test_straggler_watchdog_emergency_checkpoint(tmp_path):
    import time as _time

    import jax as _jax
    from repro.launch.step import make_train_step

    cfg = get_smoke_config("qwen3-0.6b")
    opt = adamw.OptConfig(lr=1e-3)
    data = DataConfig(seed=0, global_batch=4, seq_len=16)
    d = str(tmp_path)

    real_step = _jax.jit(make_train_step(cfg, opt))
    calls = {"n": 0}

    def wrapped(state, batch):
        calls["n"] += 1
        if calls["n"] == 30:      # simulated straggler: one 1s stall
            _time.sleep(1.0)
        return real_step(state, batch)

    with pytest.raises(StragglerEvent):
        train(cfg, opt, data,
              TrainLoopConfig(total_steps=40, ckpt_every=100,
                              straggler_factor=3.0),
              d, train_step=wrapped, log=lambda *_: None)
    assert ckpt.latest_step(d) is not None    # emergency save happened


def test_adamw_factored_v_close_to_full():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((256, 256)) * 0.01,
                          jnp.float32)}
    full = adamw.OptConfig(lr=1e-2, factored_v=False)
    fact = adamw.OptConfig(lr=1e-2, factored_v=True)
    sf = adamw.init_state(p, full)
    sv = adamw.init_state(p, fact)
    pf, sf, _ = adamw.apply_updates(p, g, sf, full)
    pv, sv, _ = adamw.apply_updates(p, g, sv, fact)
    # factored v approximates full v: update directions must agree closely
    uf = np.asarray(pf["w"] - p["w"]).ravel()
    uv = np.asarray(pv["w"] - p["w"]).ravel()
    cos = float(uf @ uv / (np.linalg.norm(uf) * np.linalg.norm(uv)))
    # rank-1 v is a coarse approximation on white-noise gradients; 0.8
    # cosine matches Adafactor's own behaviour on this input
    assert cos > 0.7, cos
    assert np.all(np.isfinite(uv))
    assert isinstance(sv["v"]["w"], dict)     # actually factored


def test_elastic_reshard_restore(tmp_path):
    """A checkpoint written unsharded restores onto a 1-device 'mesh' with
    explicit shardings (the elastic-restart path at CPU scale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    t = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(d, 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    like = jax.eval_shape(lambda: t)
    r = ckpt.restore(d, 1, like, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]
