"""Distribution-layer tests: sharding rules, multi-device lowering on a
small mesh (subprocess with forced device count), gradient-compression
numerics, and DP-vs-single-device equivalence."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch import specs as S
from repro.optim import adamw
from repro.parallel import sharding as shd


class FakeMesh:
    """Shape-only mesh stand-in for spec computation (no devices)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_specs_attention_tp():
    cfg = get_smoke_config("qwen2.5-14b")
    params = S.abstract_params(cfg)
    spec = shd.param_specs(params, MESH, cfg)
    blk = spec["dense_blocks"]
    # stacked leading dim replicated; q heads too small in smoke cfg, but
    # full cfg must shard heads on model
    full = get_smoke_config("qwen2.5-14b").replace(
        n_layers=2, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=13824)
    pf = S.abstract_params(full)
    sf = shd.param_specs(pf, MESH, full)
    # qwen2.5: 40 q-heads and 8 kv-heads are both indivisible by the
    # 16-way model axis -> the rule engine falls back to head_dim (128)
    assert sf["dense_blocks"]["attn"]["wq"] == P(None, None, None, "model")
    assert sf["dense_blocks"]["attn"]["wk"] == P(None, None, None, "model")
    assert sf["dense_blocks"]["mlp"]["w_gate"] == P(None, None, "model")
    assert sf["dense_blocks"]["mlp"]["w_down"] == P(None, "model", None)
    assert sf["embed"][0] is None or sf["embed"] is not None  # exists


def test_param_specs_moe_ep():
    cfg = get_smoke_config("deepseek-v3-671b").replace(
        n_experts=256, moe_d_ff=2048, d_model=7168)
    params = S.abstract_params(cfg)
    spec = shd.param_specs(params, MESH, cfg)
    assert spec["moe_blocks"]["moe"]["w_gate"][1] == "model"  # experts on EP


def test_fsdp_mode_adds_data_axis():
    full = get_smoke_config("deepseek-v3-671b").replace(
        n_layers=2, d_model=7168, n_experts=32, moe_d_ff=2048,
        kv_lora_rank=512, q_lora_rank=1536, shard_mode="fsdp_tp")
    pf = S.abstract_params(full)
    sf = shd.param_specs(pf, MESH_MP, full)
    wg = sf["moe_blocks"]["moe"]["w_gate"]  # (L, E, D, F) big
    flat = [a for d in wg if d for a in (d if isinstance(d, tuple) else (d,))]
    assert "data" in flat, wg


def test_cache_specs_shard_batch_and_headdim():
    cfg = get_smoke_config("qwen2.5-14b").replace(head_dim=128)
    model_cache = jax.eval_shape(
        lambda: __import__("repro.models.lm", fromlist=["x"]).init_cache(
            cfg, 128, 32768))
    spec = shd.cache_specs(cfg, MESH, model_cache, 128, 32768)
    k = spec["dense_blocks"]["k"]
    assert k[1] in ("data", ("data",))   # batch on the data axis
    assert k[4] == "model"            # head_dim (kv heads not divisible)
    assert k[2] is None               # never shard the max_len dim


def test_batch_specs():
    cfg = get_smoke_config("qwen3-0.6b")
    batch = S.input_specs(cfg, "train_4k")
    spec = shd.batch_specs(cfg, MESH_MP, batch)
    assert spec["tokens"] == P(("pod", "data"))


def test_compressed_psum_error_feedback():
    """bf16 all-reduce with error feedback: telescoping residuals keep the
    long-run mean unbiased (vs plain bf16 rounding which drifts)."""
    from repro.parallel.collectives import compressed_psum, zeros_like_residual
    mesh = jax.make_mesh((1,), ("d",))
    g = {"w": jnp.full((256,), 1.0 + 2.0**-12)}  # not bf16-representable

    def run_steps(n):
        res = zeros_like_residual(g)
        total = jnp.zeros_like(g["w"])
        from functools import partial
        from jax.experimental.shard_map import shard_map

        @partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        def one(gw, rw):
            red, nr = compressed_psum({"w": gw}, {"w": rw}, "d")
            return red["w"], nr["w"]

        for _ in range(n):
            red, res_w = one(g["w"], res["w"])
            res = {"w": res_w}
            total = total + red
        return total / n

    avg = run_steps(64)   # residual cycle is 2^(8-12+1)=32 steps at RN-even
    err_fb = float(jnp.max(jnp.abs(avg - g["w"])))
    plain = g["w"].astype(jnp.bfloat16).astype(jnp.float32)
    err_plain = float(jnp.max(jnp.abs(plain - g["w"])))
    # the RN-even residual cycle gives mean error <= err_plain/4 (it hits
    # the bound exactly when steps is a multiple of the 16-step cycle)
    assert err_fb <= err_plain / 4 + 1e-12


SUBPROC_DP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.optim import adamw
    from repro.launch.step import make_train_step

    cfg = get_smoke_config("qwen3-0.6b")
    opt = adamw.OptConfig(lr=1e-3)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init_state(params, opt)}
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
    }
    step = make_train_step(cfg, opt)
    # single device
    s1, m1 = jax.jit(step)(state, batch)
    # 8-way DP
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    bs = {k: NamedSharding(mesh, P("data")) for k in batch}
    batch_sharded = {k: jax.device_put(v, bs[k]) for k, v in batch.items()}
    s8, m8 = jax.jit(step)(state, batch_sharded)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1["params"], s8["params"])
    worst = max(jax.tree.leaves(d))
    print("WORST", worst)
    assert worst < 5e-5, worst
    print("OK")
""")


def test_dp_matches_single_device_subprocess():
    r = subprocess.run([sys.executable, "-c", SUBPROC_DP],
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=600)
    assert "OK" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])


def test_hlo_analyzer_trip_counts():
    from repro.launch.hlo_cost import analyze_hlo

    def body(x, w):
        return jnp.tanh(x @ w), None

    w = jnp.zeros((8, 64, 64))
    x = jnp.zeros((16, 64))
    txt = jax.jit(lambda x, w: jax.lax.scan(body, x, w)[0]) \
        .lower(x, w).compile().as_text()
    res = analyze_hlo(txt)
    assert res["dot_flops"] == 2 * 16 * 64 * 64 * 8
    assert res["unknown_trip_counts"] == 0


def test_dp_over_model_specs_replicate_params():
    cfg = get_smoke_config("mamba2-130m").replace(dp_over_model=True)
    params = S.abstract_params(cfg)
    spec = shd.param_specs(params, MESH, cfg)
    assert all(s == P() for s in jax.tree.leaves(
        spec, is_leaf=lambda x: isinstance(x, P)))
    batch = S.input_specs(cfg, "train_4k")
    bspec = shd.batch_specs(cfg, MESH, batch)
    assert bspec["tokens"] == P(("data", "model"))


def test_mixed_policy_knob_is_numerically_sane():
    """attn_policy=bf16 must stay close to the paper-faithful forward."""
    import numpy as np
    from repro.models import get_model
    cfg6 = get_smoke_config("qwen3-0.6b")
    cfgm = cfg6.replace(attn_policy="bf16")
    m6, mm = get_model(cfg6), get_model(cfgm)
    params = m6.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg6.vocab_size, (2, 32))),
        "labels": jnp.asarray(rng.integers(0, cfg6.vocab_size, (2, 32))),
    }
    l6 = float(m6.loss_fn(params, batch)[0])
    lm = float(mm.loss_fn(params, batch)[0])
    assert abs(l6 - lm) < 0.02, (l6, lm)


def test_ep2d_specs_when_divisible():
    cfg = get_smoke_config("deepseek-v3-671b").replace(
        n_experts=256, ep_mode="2d")
    params = S.abstract_params(cfg)
    spec = shd.param_specs(params, MESH, cfg)
    wg = spec["moe_blocks"]["moe"]["w_gate"]
    assert wg[1] == ("model", "data"), wg
