"""Deterministic micro-shim for ``hypothesis`` (used only when absent).

Implements the tiny subset this suite uses — ``@given``, ``@settings``,
``strategies.integers`` and ``strategies.sampled_from`` — by running the
decorated test over a fixed, seeded set of examples.  This is *not* a
property-based testing engine (no shrinking, no coverage-guided search);
it exists so minimal containers without the real ``hypothesis`` package
still execute every property test deterministically instead of erroring
at collection time.  Install ``hypothesis`` (see pyproject's ``[dev]``
extra) to get the real search behavior.
"""
from __future__ import annotations

import functools
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value=0, max_value=2**63 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(items):
        items = list(items)
        return _Strategy(lambda rng: rng.choice(items))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


st = strategies

_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        n = getattr(fn, "_fallback_settings",
                    {"max_examples": _DEFAULT_MAX_EXAMPLES})["max_examples"]

        # NB: no functools.wraps — pytest must not see the inner function's
        # strategy-valued parameters (it would treat them as fixtures).
        def wrapper():
            for i in range(n):
                rng = random.Random(0xC0FFEE + 7919 * i)
                args = tuple(s.example(rng) for s in arg_strats)
                kwargs = {k: s.example(rng) for k, s in kw_strats.items()}
                fn(*args, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


class HealthCheck:  # referenced by some suites via settings(suppress_...)
    all = staticmethod(lambda: [])
