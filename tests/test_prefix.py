"""Shared-prefix serving: COW prefix cache, chunked prefill, async overlap.

The load-bearing contract (ISSUE 9): with float32 pools, greedy engine
output is **token-identical** with each serving knob on vs off —
``prefix_cache`` (copy-on-write page sharing), ``chunked_prefill``
(prompts prefilled in chunks interleaved with decode), ``async_sched``
(consume-at-next-step overlap) — individually and all together, across
the transformer / GQA+window+softcap / MLA+MoE parity archs.  On top:
refcounted-pool units, prefix-tree units (insert / lookup / COW split /
refcount / eviction under pressure), shared-prefix-then-defrag parity,
and chaos coverage for the ``prefix.lookup`` and ``prefill.chunk`` fault
sites.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import faults, numerics, obs
from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serving import (Engine, PagePool, PagePoolError, PrefixCache,
                           SamplingParams)


_PARAMS_CACHE = {}


def _model_and_params(arch):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        _PARAMS_CACHE[arch] = (cfg, model,
                               model.init(jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[arch]


# ====================================================== refcounted pool

def test_pool_share_and_free_refcounts():
    pool = PagePool(8, 4)
    pages = pool.alloc(2)
    assert [pool.refcount(p) for p in pages] == [1, 1]
    pool.share(pages)
    assert [pool.refcount(p) for p in pages] == [2, 2]
    free_before = pool.num_free
    pool.free(pages)                      # one owner down: still live
    assert [pool.refcount(p) for p in pages] == [1, 1]
    assert pool.num_free == free_before
    pool.free(pages)                      # last owner: back on free list
    assert [pool.refcount(p) for p in pages] == [0, 0]
    assert pool.num_free == free_before + 2


def test_pool_share_of_non_live_page_raises():
    pool = PagePool(8, 4)
    with pytest.raises(PagePoolError):
        pool.share([3])
    pages = pool.alloc(1)
    pool.free(pages)
    with pytest.raises(PagePoolError):
        pool.share(pages)                 # freed page can't gain owners


def test_pool_double_free_still_raises_with_refcounts():
    pool = PagePool(8, 4)
    pages = pool.alloc(1)
    pool.free(pages)
    with pytest.raises(PagePoolError):
        pool.free(pages)


def test_pool_defrag_carries_refcounts():
    pool = PagePool(10, 4)
    a = pool.alloc(2)
    b = pool.alloc(2)
    pool.share(b)
    pool.free(a)                          # holes below b
    mapping = pool.defrag()
    assert [pool.refcount(mapping[p]) for p in b] == [2, 2]
    assert sorted(mapping[p] for p in b) == [1, 2]


# ========================================================== prefix tree

def _toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(0, 97, n)]


def test_tree_insert_then_match_full_pages_only():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    toks = _toks(10)                      # 2 full pages + 2-token tail
    pages = pool.alloc(3)
    assert cache.insert(toks, pages) == 2   # the partial page is ignored
    got, matched = cache.match(toks)
    assert got == pages[:2] and matched == 8
    # the tree holds one reference per node on top of the allocator's
    assert [pool.refcount(p) for p in pages] == [2, 2, 1]
    # a diverging prefix stops the walk at the divergence point
    other = list(toks)
    other[5] = (other[5] + 1) % 97
    got, matched = cache.match(other)
    assert got == pages[:1] and matched == 4


def test_tree_insert_is_idempotent_no_duplicate_refs():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    toks = _toks(8)
    pages = pool.alloc(2)
    assert cache.insert(toks, pages) == 2
    dup = pool.alloc(2)                   # same content, different pages
    assert cache.insert(toks, dup) == 0   # existing nodes keep their page
    assert [pool.refcount(p) for p in pages] == [2, 2]
    assert [pool.refcount(p) for p in dup] == [1, 1]


def test_tree_eviction_is_lru_and_skips_shared_pages():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    a, b = _toks(4, seed=1), _toks(4, seed=2)
    pa, pb = pool.alloc(1), pool.alloc(1)
    cache.insert(a, pa)
    cache.insert(b, pb)
    pool.free(pa)
    pool.free(pb)                         # now only the cache owns both
    cache.match(a)                        # touch a: b becomes LRU
    assert cache.evict_for(1) == 1
    assert cache.match(b) == ([], 0)      # b evicted...
    assert cache.match(a) == (pa, 4)      # ...a survives
    # a page still shared with a "request" is never evicted
    pool.share(pa)
    assert cache.evict_for(1) == 0
    pool.free(pa)
    assert cache.evict_for(1) == 1 and cache.n_nodes == 0
    assert pool.num_free == pool.num_pages - 1


def test_tree_eviction_deepest_first_within_a_chain():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    toks = _toks(12, seed=3)              # one 3-node chain
    pages = pool.alloc(3)
    cache.insert(toks, pages)
    pool.free(pages)
    assert cache.evict_for(2) == 2        # leaves peel off the tail
    got, matched = cache.match(toks)
    assert got == pages[:1] and matched == 4


def test_tree_remap_follows_defrag():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    hole = pool.alloc(2)
    toks = _toks(8, seed=4)
    pages = pool.alloc(2)
    cache.insert(toks, pages)
    pool.free(pages)
    pool.free(hole)                       # holes below the cached pages
    mapping = pool.defrag()
    cache.remap(mapping)
    got, matched = cache.match(toks)
    assert got == [mapping[p] for p in pages] and matched == 8
    assert all(pool.refcount(p) == 1 for p in got)


# ============================================== engine parity (the gate)

PARITY_ARCHS = ["qwen3-0.6b", "gemma2-9b", "deepseek-v3-671b"]
KNOBS = {
    "prefix": dict(prefix_cache=True),
    "chunked": dict(chunked_prefill=16),
    "async": dict(async_sched=True),
    "all": dict(prefix_cache=True, chunked_prefill=16, async_sched=True),
}


def _engine_tokens(cfg, params, prompts, nc, gen=4, max_slots=2,
                   num_pages=25, **kw):
    eng = Engine(cfg, params, max_slots=max_slots, num_pages=num_pages,
                 page_size=16, max_pages_per_slot=8, numerics_config=nc,
                 cache_dtype=jnp.float32, **kw)
    rids = [eng.add_request(p, SamplingParams(max_tokens=gen, seed=i))
            for i, p in enumerate(prompts)]
    out = eng.run()
    return [list(out[r]) for r in rids], eng


def _shared_prompts(cfg, B=3, P=24, shared=16, seed=0):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (B, P))
    prompts[:, :shared] = prompts[0, :shared]
    return prompts


@pytest.mark.parametrize("arch", PARITY_ARCHS)
@pytest.mark.parametrize("knob", sorted(KNOBS))
def test_engine_token_identical_with_knob_on_vs_off(arch, knob):
    """The acceptance gate: each serving knob (and all together) leaves
    greedy engine output bitwise unchanged, across GQA / window+softcap /
    MLA+MoE archs, with f32 pools carrying the reuse path exactly."""
    cfg, model, params = _model_and_params(arch)
    prompts = _shared_prompts(cfg)
    base = numerics.active()
    ref, _ = _engine_tokens(cfg, params, prompts, base)
    got, eng = _engine_tokens(cfg, params, prompts,
                              base.replace(**KNOBS[knob]))
    assert got == ref
    stats = eng.stats()
    if "prefix_cache" in KNOBS[knob]:
        assert stats["prefix_hits"] >= 1
        assert stats["prefix_tokens_reused"] >= 16
    if "chunked_prefill" in KNOBS[knob]:
        assert stats["prefill_chunks"] >= 1


def test_full_prompt_hit_forces_deterministic_cow_split():
    """Identical prompts: the last position is always recomputed, so a
    fully-cached prompt rewrites its final page — which is shared, so a
    COW split must fire (and output stays identical)."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    prompts = np.tile(_shared_prompts(cfg, B=1, P=32, shared=32), (3, 1))
    base = numerics.active()
    ref, _ = _engine_tokens(cfg, params, prompts, base, max_slots=1)
    got, eng = _engine_tokens(cfg, params, prompts,
                              base.replace(prefix_cache=True), max_slots=1)
    assert got == ref
    stats = eng.stats()
    assert stats["prefix_hits"] == 2 and stats["cow_splits"] == 2
    assert stats["prefix_tokens_reused"] == 32


def test_eviction_under_pool_pressure_keeps_parity():
    """Distinct prompts fill the cache; a pool too small for cache +
    resident set forces LRU eviction on admission, transparently."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (3, 32))    # no sharing
    base = numerics.active()
    ref, _ = _engine_tokens(cfg, params, prompts, base, max_slots=1,
                            num_pages=5)
    got, eng = _engine_tokens(cfg, params, prompts,
                              base.replace(prefix_cache=True),
                              max_slots=1, num_pages=5)
    assert got == ref
    assert eng.stats()["prefix_evictions"] >= 1


def test_shared_prefix_then_defrag_stays_token_identical():
    """Satellite (a): defrag while the cache holds shared pages — nodes
    remap, refcounts travel, and a later hit still reuses them."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    prompts = _shared_prompts(cfg)
    base = numerics.active()
    ref, _ = _engine_tokens(cfg, params, prompts, base, max_slots=1)

    nc = base.replace(prefix_cache=True)
    eng = Engine(cfg, params, max_slots=1, num_pages=25, page_size=16,
                 max_pages_per_slot=8, numerics_config=nc,
                 cache_dtype=jnp.float32)
    rids = [eng.add_request(p, SamplingParams(max_tokens=4, seed=i))
            for i, p in enumerate(prompts)]
    while len([r for r in rids if eng._requests[r].finished]) < 1:
        eng.step()
    eng.defragment()                      # cached pages move mid-serve
    eng.run()
    out = eng.results()
    assert [list(out[r]) for r in rids] == ref
    assert eng.stats()["prefix_hits"] >= 1
    # bookkeeping invariant: every cached node's page is live and its
    # refcount accounts for the tree's own reference
    stack = list(eng.prefix._children.values())
    while stack:
        node = stack.pop()
        assert eng.pool.refcount(node.page) >= 1
        stack.extend(node.children.values())


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt admitted behind a running request must not stall it:
    the chunk phase advances one chunk per step while decode proceeds."""
    cfg, model, params = _model_and_params("qwen3-0.6b")
    rng = np.random.default_rng(2)
    short, long = rng.integers(0, cfg.vocab_size, (2, 64))
    base = numerics.active()
    ref, _ = _engine_tokens(cfg, params, [short[:16], long], base,
                            gen=8, num_pages=16)
    nc = base.replace(chunked_prefill=16)
    eng = Engine(cfg, params, max_slots=2, num_pages=16, page_size=16,
                 max_pages_per_slot=8, numerics_config=nc,
                 cache_dtype=jnp.float32)
    r0 = eng.add_request(short[:16], SamplingParams(max_tokens=8, seed=0))
    r1 = eng.add_request(long, SamplingParams(max_tokens=8, seed=1))
    eng.step()                            # r0 prefills; r1 starts chunking
    assert eng._requests[r1].prefill_done > 0
    decoded_before = len(eng._requests[r0].out)
    eng.step()                            # r1 still chunking...
    assert len(eng._requests[r0].out) > decoded_before   # ...r0 decodes
    eng.run()
    out = eng.results()
    assert [list(out[r0]), list(out[r1])] == ref
    assert eng.n_prefill_chunks == 4      # 64 tokens / 16-token chunks


# ================================================================ chaos

def test_poisoned_lookup_degrades_to_full_prefill_identically():
    cfg, model, params = _model_and_params("qwen3-0.6b")
    prompts = _shared_prompts(cfg)
    base = numerics.active()
    ref, _ = _engine_tokens(cfg, params, prompts, base)
    plan = faults.FaultPlan([faults.FaultSpec("prefix.lookup", every=1)])
    with faults.use(plan):
        got, eng = _engine_tokens(cfg, params, prompts,
                                  base.replace(prefix_cache=True))
    assert got == ref
    assert eng.stats()["prefix_hits"] == 0         # every lookup poisoned
    assert plan.log and all(s == "prefix.lookup" for s, _ in plan.log)


def test_chunk_fault_requeues_request_token_identically():
    cfg, model, params = _model_and_params("qwen3-0.6b")
    prompts = _shared_prompts(cfg)
    base = numerics.active()
    ref, _ = _engine_tokens(cfg, params, prompts, base)
    plan = faults.FaultPlan([faults.FaultSpec("prefill.chunk", at=(0,))])
    with faults.use(plan):
        got, eng = _engine_tokens(
            cfg, params, prompts,
            base.replace(prefix_cache=True, chunked_prefill=16))
    assert got == ref
    assert eng.stats()["prefill_faults"] == 1
    assert plan.log == [("prefill.chunk", 0)]


def test_chunk_fault_three_strikes_finishes_with_error():
    cfg, model, params = _model_and_params("qwen3-0.6b")
    prompts = _shared_prompts(cfg, B=1, P=32)
    nc = numerics.active().replace(chunked_prefill=16)
    plan = faults.FaultPlan([faults.FaultSpec("prefill.chunk", every=1)])
    eng = Engine(cfg, params, max_slots=1, num_pages=25, page_size=16,
                 max_pages_per_slot=8, numerics_config=nc,
                 cache_dtype=jnp.float32)
    rid = eng.add_request(prompts[0], SamplingParams(max_tokens=4))
    with faults.use(plan):
        out = eng.run()
    assert out[rid].finish_reason == "error" and list(out[rid]) == []
    assert eng.stats()["prefill_faults"] == Engine.MAX_PREFILL_FAULTS
    # a failed chunked prefill leaks nothing: pool back to empty
    assert eng.pool.num_live == 0


# ========================================================== stats / obs

def test_prefix_stats_surface_in_engine_and_obs_snapshot():
    cfg, model, params = _model_and_params("qwen3-0.6b")
    prompts = np.tile(_shared_prompts(cfg, B=1, P=32, shared=32), (2, 1))
    nc = numerics.active().replace(prefix_cache=True)
    _, eng = _engine_tokens(cfg, params, prompts, nc, max_slots=1)
    stats = eng.stats()
    for key in ("prefix_hits", "prefix_tokens_reused", "cow_splits",
                "prefix_evictions", "prefill_chunks"):
        assert key in stats
    src = obs.snapshot()["sources"]["serving/engine"]
    assert src["prefix_hits"] >= stats["prefix_hits"] >= 1
    assert src["prefix_tokens_reused"] >= stats["prefix_tokens_reused"]
