"""Chaos battery: fault injection, the dispatch circuit breaker, and the
serving engine's graceful-degradation paths.

Every recovery path the resilience layer promises is driven here under an
injected fault schedule and held to the invariants that matter:

  * **conservation** — after every engine step, free + held pages equal
    ``num_pages - 1`` (the scrap page is never handed out);
  * **liveness** — the engine drains in a bounded number of steps (no
    deadlock, no livelock);
  * **parity** — fault-free requests stay token-identical to the dense
    oracle even while a neighbouring slot is being faulted;
  * **breaker** — the dispatch circuit breaker opens on repeated kernel
    failure, declines during cooldown, half-opens, and closes on a
    healthy probe;
  * **determinism** — the same fault plan over the same workload yields
    the same trip sequence and the same stats, run after run.

NB breaker updates happen at *trace time* (dispatch runs when jit
traces), so the breaker integration tests drive the eager ``repro.matmul``
verb — a jitted caller that hits its compiled cache never re-enters
dispatch (see kernels/guard.py's docstring).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from repro import faults, numerics
from repro.configs import get_smoke_config
from repro.kernels import guard, tuning
from repro.models import get_model
from repro.serving import (Engine, EngineOverloaded, FinishReason, PagePool,
                           RequestRejected, RequestResult, SamplingParams,
                           Scheduler)


def _model_and_params(arch="qwen3-0.6b"):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


_CACHE = {}


def _cached_model_and_params(arch="qwen3-0.6b"):
    if arch not in _CACHE:
        _CACHE[arch] = _model_and_params(arch)
    return _CACHE[arch]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n) for n in lens]


def _dense_ref(cfg, params, prompt, n):
    from repro.launch.serve import generate_dense
    return np.asarray(generate_dense(
        cfg, params, jnp.asarray(prompt, jnp.int32)[None], n))[0]


def _drain_checked(engine, max_steps=500):
    """Run the engine to drain, asserting page conservation every step
    and bounding the step count (liveness)."""
    steps = 0
    while engine.sched.has_work:
        engine.step()
        steps += 1
        held = sum(len(r.pages) for r in engine.sched.running.values())
        assert engine.pool.num_free + held == engine.pool.num_pages - 1, \
            f"page leak at step {steps}"
        assert steps <= max_steps, "engine failed to drain (deadlock?)"
    return engine.results()


@pytest.fixture(autouse=True)
def _clean_breaker():
    guard.reset()
    guard.configure(threshold=2, cooldown=8)
    yield
    guard.reset()
    guard.configure(threshold=2, cooldown=8)


# ========================================================== fault plans

def test_fault_spec_triggers_and_budget():
    s = faults.FaultSpec("pool.alloc", at=(0, 3))
    assert s.triggers(0) and not s.triggers(1) and s.triggers(3)
    s = faults.FaultSpec("pool.alloc", every=3)
    assert [s.triggers(i) for i in range(6)] == [
        False, False, True, False, False, True]
    plan = faults.FaultPlan([faults.FaultSpec("prefill", every=1, times=2)])
    fired = [plan.poke("prefill") is not None for _ in range(5)]
    assert fired == [True, True, False, False, False]   # budget exhausted


def test_fault_plan_parsing_and_unknown_sites():
    plan = faults.plan_from_spec(
        "pool.alloc@0:2; decode.slow@every=4:arg=3 ;"
        "kernel.matmul@p=0.5:seed=7:times=1")
    a, b, c = plan.specs
    assert a.at == (0, 2) and b.every == 4 and b.arg == 3
    assert c.p == 0.5 and c.seed == 7 and c.times == 1
    with pytest.raises(ValueError):
        faults.FaultSpec("no.such.site")
    with pytest.raises(ValueError):
        faults.plan_from_spec("pool.alloc@bogus=1")
    with pytest.raises(ValueError):
        faults.plan_from_spec("just-a-site-no-at")
    with pytest.raises(KeyError):
        faults.FaultPlan().poke("no.such.site")


def test_fault_plan_probabilistic_is_seed_deterministic():
    mk = lambda: faults.plan_from_spec("kernel.matmul@p=0.3:seed=11")
    fire = lambda p: [p.poke("kernel.matmul") is not None
                      for _ in range(64)]
    a, b = fire(mk()), fire(mk())
    assert a == b and any(a) and not all(a)
    other = fire(faults.plan_from_spec("kernel.matmul@p=0.3:seed=12"))
    assert other != a                       # the seed actually matters


def test_fault_context_nesting_and_masking():
    outer = faults.FaultPlan([faults.FaultSpec("prefill", every=1)])
    with faults.use(outer):
        assert faults.poke("prefill") is not None
        with faults.use(None):              # inner fault-free scope
            assert faults.poke("prefill") is None
        assert faults.poke("prefill") is not None
    assert faults.active() is None


def test_fault_env_plan_roundtrip(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "pool.alloc@0")
    plan = faults.reload_env_plan()
    assert plan is not None and plan.specs[0].site == "pool.alloc"
    assert faults.active() is plan
    monkeypatch.delenv("REPRO_FAULTS")
    assert faults.reload_env_plan() is None


def test_use_reset_replays_the_same_schedule():
    plan = faults.FaultPlan([faults.FaultSpec("pool.alloc", at=(1,))])
    runs = []
    for _ in range(2):
        with faults.use(plan):
            runs.append([faults.poke("pool.alloc") is not None
                         for _ in range(3)])
    assert runs[0] == runs[1] == [False, True, False]
    assert plan.log == [("pool.alloc", 1)]


# ======================================================= circuit breaker

def test_breaker_unit_transitions():
    guard.configure(threshold=2, cooldown=3)
    key = ("cpu", "matmul", "unit-test")
    assert guard.state(key) == "closed" and guard.allow(key)
    guard.failure(key)
    assert guard.state(key) == "closed"       # 1 < threshold
    guard.failure(key)
    assert guard.state(key) == "open"
    for _ in range(3):
        assert not guard.allow(key)           # cooldown declines
    assert guard.allow(key)                   # probe allowed
    assert guard.state(key) == "half_open"
    guard.failure(key)                        # probe fails -> reopen
    assert guard.state(key) == "open"
    for _ in range(3):
        assert not guard.allow(key)
    assert guard.allow(key)
    guard.success(key)                        # probe succeeds -> close
    assert guard.state(key) == "closed"
    st = guard.stats()
    row = st["keys"]["cpu/matmul/unit-test"]
    assert row["opens"] == 2 and row["closes"] == 1
    assert st["totals"]["declined"] == 6


def test_breaker_success_resets_consecutive_failures():
    guard.configure(threshold=3, cooldown=2)
    key = ("cpu", "matmul", "reset-test")
    guard.failure(key)
    guard.failure(key)
    guard.success(key)                        # streak broken
    guard.failure(key)
    guard.failure(key)
    assert guard.state(key) == "closed"       # never reached 3 in a row


def _eager_kernel_scope():
    """The numerics scope under which repro.matmul dispatches the fused
    kernel eagerly on CPU (interpret mode, no size gate, no tuner IO)."""
    return numerics.use(policy="tcec_bf16x6", force=True, interpret=True,
                        min_dim=0, tune="off")


def test_guarded_dispatch_falls_back_and_quarantines():
    """Injected kernel failures: every call still returns the correct
    product (XLA fallback), the breaker opens after the threshold, and
    cooldown calls skip the kernel entirely."""
    rng = np.random.default_rng(0)
    # 128-aligned shapes: un-padded, where kernel and fallback agree
    # bitwise (padding changes the K-blocking, hence the rounding order)
    a = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    guard.configure(threshold=2, cooldown=2)
    with _eager_kernel_scope():
        ref = np.asarray(repro.matmul(a, b))          # healthy baseline
        plan = faults.plan_from_spec("kernel.matmul@0:1")
        with faults.use(plan):
            outs = [np.asarray(repro.matmul(a, b)) for _ in range(6)]
        # call 0,1: fault -> fallback; 2,3: declined (cooldown);
        # 4: half-open probe succeeds -> closed; 5: healthy
        for out in outs:
            np.testing.assert_array_equal(out, ref)
        assert plan.log == [("kernel.matmul", 0), ("kernel.matmul", 1)]
    totals = guard.counters()
    assert totals["failures"] == 2 and totals["declined"] == 2
    assert totals["opens"] == 1 and totals["closes"] == 1
    assert totals["half_opens"] == 1


def test_guard_off_propagates_kernel_errors():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((160, 160)), jnp.float32)
    with _eager_kernel_scope(), numerics.use(guard=False), \
            faults.use(faults.plan_from_spec("kernel.matmul@0")):
        with pytest.raises(faults.FaultInjected):
            repro.matmul(a, a)
    assert guard.counters()["failures"] == 0   # breaker never consulted


def test_guard_knob_registered_and_parsed(monkeypatch):
    assert "REPRO_GUARD" in numerics.ENV_VARS
    assert "REPRO_FAULTS" in numerics.ENV_VARS
    monkeypatch.setenv("REPRO_GUARD", "0")
    assert numerics.NumericsConfig.from_env().guard is False
    monkeypatch.delenv("REPRO_GUARD")
    assert numerics.NumericsConfig.from_env().guard is True


# ================================================== tuning-cache guards

def test_tuning_cache_rejects_corrupt_entries(tmp_path):
    import json
    path = tmp_path / "tune.json"
    path.write_text(json.dumps({
        "version": tuning.CACHE_VERSION,
        "entries": {
            "good": {"block": [128, 128, 256], "ms": 0.4},
            "bad-type": {"block": "128x128"},
            "bad-len": {"block": [128, 128, 128, 128]},
            "bad-val": {"block": [128, 0, 128]},
            "bad-ms": {"block": [128, 128, 128], "ms": "fast"},
        }}))
    cache = tuning.BlockCache(path=str(path))
    assert cache.get("good") == {"block": [128, 128, 256], "ms": 0.4}
    for key in ("bad-type", "bad-len", "bad-val", "bad-ms"):
        assert cache.get(key) is None, key
        assert cache.get(key) is None          # stays a miss


def test_tuning_cache_survives_injected_corruption(tmp_path):
    import json
    path = tmp_path / "tune.json"
    path.write_text(json.dumps({
        "version": tuning.CACHE_VERSION,
        "entries": {"k": {"block": [256, 128, 128], "ms": 1.0}}}))
    cache = tuning.BlockCache(path=str(path))
    with faults.use(faults.plan_from_spec("tuning.cache@0")):
        assert cache.get("k") is None          # injected corruption -> miss
        # the corrupt entry was dropped; a clean re-read is also a miss
        # until the tuner re-persists it
        assert cache.get("k") is None
    cache.put("k", {"block": [128, 128, 128], "ms": 0.5}, persist=True)
    assert cache.get("k")["block"] == [128, 128, 128]


def test_autotune_heals_through_corrupt_cache(tmp_path):
    """End-to-end: a corrupt on-disk entry reads as a miss and the tuner
    re-derives a valid block instead of crashing."""
    path = tmp_path / "tune.json"
    path.write_text('{"version": "garbage"')   # truncated JSON wholesale
    with numerics.use(tune="off", tune_cache=str(path)):
        block = tuning.get_block(256, 256, 256, "tcec_bf16x6")
    assert len(block) == 3 and all(b >= 128 for b in block)


# ===================================================== engine chaos runs

_ENGINE_KW = dict(max_slots=2, num_pages=64, page_size=4)


def _drive(plan=None, lens=(5, 9), max_tokens=6, seed=3, **kw):
    cfg, model, params = _cached_model_and_params()
    prompts = _prompts(cfg, lens, seed=seed)
    engine = Engine(cfg, params, **{**_ENGINE_KW, **kw})
    rids = [engine.add_request(p, SamplingParams(max_tokens=max_tokens))
            for p in prompts]
    if plan is not None:
        with faults.use(plan):
            out = _drain_checked(engine)
    else:
        out = _drain_checked(engine)
    return cfg, params, prompts, engine, rids, out


def test_chaos_alloc_faults_delay_but_preserve_parity():
    """Transient pool exhaustion delays admission; once admitted, every
    request still produces exactly its dense-oracle tokens."""
    plan = faults.plan_from_spec("pool.alloc@0:1:2")
    cfg, params, prompts, engine, rids, out = _drive(plan)
    assert len(plan.log) == 3                  # all three faults fired
    for p, rid in zip(prompts, rids):
        ref = _dense_ref(cfg, params, p, 6)
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))
        assert out[rid].finish_reason == "length"
    assert engine.pool.num_live == 0


def test_chaos_nonfinite_recovers_via_fallback_rerun():
    """One poisoned decode step: the guard bit trips, the step re-runs
    under the XLA-fallback scope, and output parity is untouched."""
    plan = faults.plan_from_spec("decode.nonfinite@0:times=1:arg=0")
    cfg, params, prompts, engine, rids, out = _drive(plan)
    st = engine.stats()
    assert st["guard_trips"] == 1 and st["fallback_reruns"] == 1
    assert st["numerics_errors"] == 0
    for p, rid in zip(prompts, rids):
        ref = _dense_ref(cfg, params, p, 6)
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))


def test_chaos_nonfinite_twice_fails_only_that_slot():
    """Fault indices 0 AND 1 hit the first run and its fallback re-run:
    the poisoned slot finishes with reason=error, the neighbour keeps
    dense parity."""
    plan = faults.plan_from_spec("decode.nonfinite@0:1:arg=0")
    cfg, params, prompts, engine, rids, out = _drive(plan)
    st = engine.stats()
    assert st["guard_trips"] == 1 and st["fallback_reruns"] == 1
    assert st["numerics_errors"] == 1
    # slot 0 (first admitted) died on its first decode step
    dead = engine._requests[rids[0]]
    assert dead.finish_reason == "error"
    assert len(out[rids[0]]) == 1              # prefill token only
    # the fault-free neighbour is untouched
    ref = _dense_ref(cfg, params, prompts[1], 6)
    np.testing.assert_array_equal(ref, np.asarray(out[rids[1]]))


def test_chaos_prefill_transient_retries_then_succeeds():
    plan = faults.plan_from_spec("prefill@0")
    cfg, params, prompts, engine, rids, out = _drive(plan)
    assert engine.stats()["prefill_faults"] == 1
    for p, rid in zip(prompts, rids):
        ref = _dense_ref(cfg, params, p, 6)
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))


def test_chaos_prefill_persistent_fails_request_not_engine():
    plan = faults.plan_from_spec("prefill@every=1")
    cfg, params, prompts, engine, rids, out = _drive(plan)
    assert all(out[r].finish_reason == "error" for r in rids)
    assert all(len(out[r]) == 0 for r in rids)
    assert engine.pool.num_live == 0           # everything rolled back


def test_chaos_slow_steps_trip_deadlines():
    cfg, model, params = _cached_model_and_params()
    prompts = _prompts(cfg, (5, 9), seed=3)
    engine = Engine(cfg, params, **_ENGINE_KW)
    fast = engine.add_request(prompts[0], SamplingParams(max_tokens=4))
    slow = engine.add_request(prompts[1], SamplingParams(max_tokens=64),
                              deadline=6)
    with faults.use(faults.plan_from_spec("decode.slow@every=2:arg=3")):
        out = _drain_checked(engine)
    assert out[fast].finish_reason == "length"
    assert out[slow].finish_reason == "timeout"
    assert engine.stats()["timeouts"] == 1
    assert engine.pool.num_live == 0


def test_queued_deadline_expires_without_running():
    cfg, model, params = _cached_model_and_params()
    engine = Engine(cfg, params, max_slots=1, num_pages=64, page_size=4)
    p = _prompts(cfg, (5, 6), seed=4)
    runner = engine.add_request(p[0], SamplingParams(max_tokens=40))
    queued = engine.add_request(p[1], SamplingParams(max_tokens=4),
                                deadline=3)
    out = _drain_checked(engine)
    assert out[queued].finish_reason == "timeout" and len(out[queued]) == 0
    assert out[runner].finish_reason == "length"


def test_backpressure_rejects_past_max_waiting():
    cfg, model, params = _cached_model_and_params()
    engine = Engine(cfg, params, max_slots=1, num_pages=64, page_size=4,
                    max_waiting=2)
    p = _prompts(cfg, (4, 4, 4, 4), seed=5)
    engine.add_request(p[0], SamplingParams(max_tokens=2))
    engine.add_request(p[1], SamplingParams(max_tokens=2))
    with pytest.raises(EngineOverloaded):
        engine.add_request(p[2], SamplingParams(max_tokens=2))
    assert engine.stats()["overloads"] == 1
    out = _drain_checked(engine)               # the admitted ones finish
    assert len(out) == 2


def test_rejection_taxonomy_counts():
    cfg, model, params = _cached_model_and_params()
    engine = Engine(cfg, params, max_slots=1, num_pages=32, page_size=4,
                    max_pages_per_slot=2)
    with pytest.raises(RequestRejected):
        engine.add_request([1, 2, 3], SamplingParams(max_tokens=0))
    with pytest.raises(RequestRejected):       # also a ValueError (compat)
        engine.add_request(list(range(16)), SamplingParams())
    with pytest.raises(ValueError):
        engine.add_request([1, 2, 3], SamplingParams(), deadline=0)
    assert engine.stats()["rejections"] == 3


# =============================================== preemption-storm battery

def test_preemption_storm_parks_and_recovers():
    """A pool sized to thrash: parking converts the storm into queueing,
    every request still finishes, page accounting holds at every step
    (incl. post-defrag), and FIFO admission order is preserved."""
    cfg, model, params = _cached_model_and_params()
    prompts = _prompts(cfg, (4, 4, 6), seed=8)
    engine = Engine(cfg, params, max_slots=2, num_pages=8, page_size=4,
                    max_pages_per_slot=8, max_preemptions=1)
    rids = [engine.add_request(p, SamplingParams(max_tokens=16))
            for p in prompts]
    steps = 0
    while engine.sched.has_work:
        engine.step()
        if steps == 5:
            engine.defragment()                # mid-storm compaction
        steps += 1
        held = sum(len(r.pages) for r in engine.sched.running.values())
        assert engine.pool.num_free + held == engine.pool.num_pages - 1
        assert steps <= 500
    out = engine.results()
    st = engine.stats()
    assert st["preemptions"] >= 2 and st["parks"] >= 1
    for p, rid in zip(prompts, rids):
        ref = _dense_ref(cfg, params, p, 16)
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))
        assert out[rid].finish_reason == "length"
    # FIFO starvation-freedom: nobody was abandoned
    assert all(engine._requests[r].finished for r in rids)
    assert engine.pool.num_live == 0


def test_storm_with_alloc_faults_still_conserves_pages():
    """Composite chaos: alloc faults on top of a thrash-prone pool."""
    plan = faults.plan_from_spec("pool.alloc@p=0.3:seed=5")
    cfg, params, prompts, engine, rids, out = _drive(
        plan, lens=(4, 6, 5), max_tokens=8, num_pages=11,
        max_pages_per_slot=8, max_preemptions=3)
    for p, rid in zip(prompts, rids):
        ref = _dense_ref(cfg, params, p, 8)
        np.testing.assert_array_equal(ref, np.asarray(out[rid]))
    assert engine.pool.num_live == 0


# ========================================================== determinism

def test_chaos_is_seed_deterministic():
    """Same fault plan, same workload -> same trip log, same stats, same
    tokens.  The acceptance criterion for the whole battery."""
    def one_run():
        plan = faults.plan_from_spec(
            "pool.alloc@p=0.25:seed=9;decode.nonfinite@2:times=1:arg=1")
        cfg, params, prompts, engine, rids, out = _drive(
            plan, lens=(4, 6, 5), max_tokens=5)
        stats = engine.stats()
        stats.pop("breaker")                   # process-global, not per-run
        return (list(plan.log), stats,
                {r: (list(v), v.finish_reason) for r, v in out.items()})
    a, b = one_run(), one_run()
    assert a[0] == b[0] and a[0]               # same (and nonempty) log
    assert a[1] == b[1]
    assert a[2] == b[2]


def test_fault_free_run_has_all_zero_counters():
    """The invariant the bench snapshot gates on: a healthy run reports
    zeros across the board."""
    guard.reset()
    cfg, params, prompts, engine, rids, out = _drive(None)
    st = engine.stats()
    for k in ("guard_trips", "fallback_reruns", "numerics_errors",
              "rejections", "overloads", "timeouts", "length_caps",
              "prefill_faults", "preemptions", "parks"):
        assert st[k] == 0, (k, st[k])
    assert all(v.finish_reason in ("stop", "length") for v in out.values())
    totals = guard.counters()
    assert totals["failures"] == 0 and totals["declined"] == 0


# ===================================================== result back-compat

def test_request_result_is_list_compatible():
    r = RequestResult([1, 2, 3], FinishReason.STOP)
    assert r == [1, 2, 3] and r[:2] == [1, 2]
    assert list(np.asarray(r)) == [1, 2, 3]
    assert r.finish_reason == "stop" and r.tokens == [1, 2, 3]
    assert "stop" in repr(r)
    assert RequestResult().finish_reason is None


def test_finish_reason_enum_values():
    assert str(FinishReason.LENGTH_CAP) == "length_cap"
    assert FinishReason.TIMEOUT == "timeout"
    assert {f.value for f in FinishReason} == {
        "stop", "length", "length_cap", "timeout", "error", "rejected",
        "overloaded"}
