"""Per-kernel validation: shape/dtype sweeps + properties vs the ref oracle.

The Pallas kernel runs under ``interpret=True`` on CPU (the kernel body
executes in Python), asserting allclose against the pure-jnp oracle in
``kernels/ref.py`` and against the f64 ground truth.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.matgen import exp_rand, relative_residual, urand
from repro.kernels import (tcec_matmul, tcec_matmul_ref, matmul_f64,
                           vmem_bytes, VMEM_BUDGET)
from repro.core.policy import get_policy


SHAPES = [
    (128, 128, 128),
    (256, 128, 384),
    (128, 256, 128),
    (384, 384, 256),
]


@pytest.mark.parametrize("policy", ["tcec_bf16x3", "tcec_bf16x6"])
@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_ref_oracle(policy, shape):
    m, n, k = shape
    a = urand((m, k), seed=m + k)
    b = urand((k, n), seed=n + k + 1)
    out = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy=policy,
                      block=(128, 128, 128), interpret=True)
    ref = tcec_matmul_ref(a, b, policy)
    # identical math; only K-block summation order differs
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("policy", ["tcec_bf16x6"])
def test_kernel_fp32_accuracy_vs_f64(policy):
    a = urand((256, 512), seed=0)
    b = urand((512, 128), seed=1)
    out = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy=policy,
                      interpret=True)
    r = relative_residual(np.asarray(out), a, b)
    r32 = relative_residual(
        a.astype(np.float32) @ b.astype(np.float32), a, b)
    assert r <= 2 * r32  # the paper's headline claim at kernel level


def test_kernel_nonaligned_shapes_pad_correctly():
    a = urand((130, 200), seed=2)
    b = urand((200, 70), seed=3)
    out = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy="tcec_bf16x6",
                      interpret=True)
    assert out.shape == (130, 70)
    ref = matmul_f64(a, b)
    rel = np.abs(np.asarray(out, dtype=np.float64) - ref) / (np.abs(ref) + 1e-30)
    assert float(np.median(rel)) < 1e-6


def test_kernel_wide_exponent_inputs():
    # bf16 = full fp32 exponent range (the tf32tf32 property)
    a = exp_rand((128, 128), -30, 20, seed=4)
    b = exp_rand((128, 128), -30, 20, seed=5)
    out = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy="tcec_bf16x6",
                      block=(128, 128, 128), interpret=True)
    r = relative_residual(np.asarray(out), a, b)
    r32 = relative_residual(a.astype(np.float32) @ b.astype(np.float32), a, b)
    assert r <= 4 * r32 + 1e-9


def test_block_picker_respects_vmem_budget():
    from repro.kernels import tuning
    for pol in ("tcec_bf16x3", "tcec_bf16x6"):
        blk = tuning.heuristic_block(4096, 4096, 4096, pol)
        assert vmem_bytes(blk, get_policy(pol)) <= VMEM_BUDGET
        assert all(s % 128 == 0 for s in blk)


@given(m=st.sampled_from([128, 256]), n=st.sampled_from([128, 256]),
       k=st.sampled_from([128, 256]), seed=st.integers(0, 10**6))
@settings(max_examples=12, deadline=None)
def test_kernel_vs_ref_property(m, n, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    out = tcec_matmul(jnp.asarray(a), jnp.asarray(b), policy="tcec_bf16x6",
                      block=(128, 128, 128), interpret=True)
    ref = tcec_matmul_ref(a, b, "tcec_bf16x6")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-5)
