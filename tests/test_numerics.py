"""repro.numerics — the context-scoped config spine.

Covers: the precedence matrix (call-site kwarg > innermost context > env
default), nested contexts, thread-local isolation, the typed env parsers
(empty / garbage values, the old truthy-parse asymmetries), the config
epoch (a context entered after a shape was jitted deterministically
re-lowers it — the fixed staleness footgun), and two structural lints:
every ``REPRO_*``/``os.environ`` read in ``src/`` goes through the
registry, and examples/benchmarks never deep-import ``repro.kernels`` or
``repro.core.policy``.
"""
import os
import re
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import numerics
from repro.numerics import ENV_VARS, NumericsConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ------------------------------------------------------------- precedence

def test_env_default_is_base_of_stack():
    assert numerics.active() == NumericsConfig.from_env()


def test_context_overrides_env_default():
    base = numerics.active()
    with numerics.use(min_dim=7, policy="tcec_bf16x3") as cfg:
        assert numerics.active() is cfg
        assert cfg.min_dim == 7 and cfg.policy == "tcec_bf16x3"
        # untouched fields inherit the outer config
        assert cfg.enabled == base.enabled
    assert numerics.active() == base


def test_nested_contexts_innermost_wins_and_unwinds():
    with numerics.use(min_dim=1, force=True):
        with numerics.use(min_dim=2):
            cfg = numerics.active()
            assert cfg.min_dim == 2
            assert cfg.force          # inherited from the outer context
        assert numerics.active().min_dim == 1
    assert numerics.active().min_dim == NumericsConfig.from_env().min_dim


def test_call_site_kwarg_beats_context():
    """The full precedence chain on one dispatch decision: the context
    forces the kernel, the call-site kwarg turns it back off."""
    a, b = _rand((128, 128), 0), _rand((128, 128), 1)
    with numerics.use(force=True, interpret=True, min_dim=0,
                      block=(128, 128, 128)):
        y_ctx = repro.matmul(a, b, policy="tcec_bf16x6")
        y_kw = repro.matmul(a, b, policy="tcec_bf16x6", enabled=False)
    with numerics.use(enabled=False):
        y_xla = repro.matmul(a, b, policy="tcec_bf16x6")
    # kernel and expansion are bit-identical with a covering K block, so
    # assert the *routing* (kwarg wins) via the kernel-call counter instead
    assert np.array_equal(np.asarray(y_kw), np.asarray(y_xla))
    assert np.allclose(np.asarray(y_ctx), np.asarray(y_xla))


def test_call_site_policy_beats_context_policy():
    a, b = _rand((64, 64), 2), _rand((64, 64), 3)
    with numerics.use(policy="bf16"):
        y_ctx = repro.matmul(a, b)                       # bf16 from context
        y_kw = repro.matmul(a, b, policy="fp32")         # kwarg wins
    y_f32 = repro.matmul(a, b, policy="fp32")
    y_bf16 = repro.matmul(a, b, policy="bf16")
    assert np.array_equal(np.asarray(y_kw), np.asarray(y_f32))
    assert np.array_equal(np.asarray(y_ctx), np.asarray(y_bf16))
    assert not np.array_equal(np.asarray(y_ctx), np.asarray(y_f32))


def test_config_instance_and_overrides_compose():
    pinned = NumericsConfig(min_dim=5, policy="tcec_bf16x6")
    with numerics.use(pinned, min_dim=9) as cfg:
        assert cfg.min_dim == 9 and cfg.policy == "tcec_bf16x6"
    with pytest.raises(TypeError):
        with numerics.use(object()):      # not a NumericsConfig
            pass


def test_unknown_override_raises():
    with pytest.raises(TypeError, match="unknown numerics option"):
        with numerics.use(minn_dim=3):
            pass
    with pytest.raises(TypeError, match="unknown numerics option"):
        repro.matmul(jnp.ones((4, 4)), jnp.ones((4, 4)), forse=True)


def test_block_coercion_and_validation():
    with numerics.use(block=[256, 256, 128]) as cfg:
        assert cfg.block == (256, 256, 128)
        assert isinstance(cfg.block, tuple)
        hash(cfg)                                   # stays hashable
    with pytest.raises(ValueError):
        NumericsConfig(attn_block=(128, 128, 128))  # wrong arity
    with pytest.raises(ValueError):
        NumericsConfig(tune="sometimes")


# ---------------------------------------------------- thread-local scoping

def test_contexts_are_thread_local():
    """A worker thread starts from the env defaults, not from another
    thread's context; its own contexts don't leak back."""
    seen = {}

    def worker():
        seen["before"] = numerics.active().min_dim
        with numerics.use(min_dim=77):
            seen["inside"] = numerics.active().min_dim
        seen["after"] = numerics.active().min_dim

    with numerics.use(min_dim=11):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert numerics.active().min_dim == 11      # unaffected by worker
    env_min = NumericsConfig.from_env().min_dim
    assert seen == {"before": env_min, "inside": 77, "after": env_min}


# --------------------------------------------------------- config epochs

def test_context_retraces_previously_jitted_shape():
    """Acceptance: a ``use(...)`` context changes dispatch decisions across
    a previously-jitted shape.  Asserted two ways: a trace counter (the
    jit must re-lower once per distinct config, and must NOT re-lower on
    re-entry of a seen config) and a kernel-call counter (the new lowering
    actually takes the other dispatch path)."""
    from repro.kernels import ops
    a, b = _rand((128, 128), 4), _rand((128, 128), 5)
    traces, kernel_calls = [], []
    real = ops.tcec_matmul
    try:
        ops.tcec_matmul = lambda *x, **kw: (kernel_calls.append(1),
                                            real(*x, **kw))[1]

        @jax.jit
        def f(a, b):
            traces.append(numerics.active().enabled)   # trace-time only
            return repro.matmul(a, b, policy="tcec_bf16x6")

        f(a, b)                      # CPU default: XLA fallback
        assert traces == [True] and kernel_calls == []
        with numerics.use(force=True, interpret=True, min_dim=0):
            f(a, b)                  # same shape -> MUST re-lower, fused
        assert traces == [True, True] and len(kernel_calls) == 1
        with numerics.use(force=True, interpret=True, min_dim=0):
            f(a, b)                  # seen config -> cached lowering
        assert traces == [True, True] and len(kernel_calls) == 1
        f(a, b)                      # ambient again -> cached lowering
        assert traces == [True, True]
        with numerics.use(enabled=False):
            f(a, b)                  # third distinct config -> re-lower
        assert traces == [True, True, False]
        assert len(kernel_calls) == 1
    finally:
        ops.tcec_matmul = real


def test_restore_to_default_context_replaces_outer_epoch():
    """Regression (review finding): a restore-to-default use(...) nested
    inside a non-default context must install its own epoch tag — with a
    nullcontext the inner trace would be keyed under the OUTER config and
    later cache-hit by it, resurrecting the stale-trace footgun."""
    from repro.kernels import ops
    a, b = _rand((128, 128), 30), _rand((128, 128), 31)
    kernel_calls = []
    real = ops.tcec_matmul
    try:
        ops.tcec_matmul = lambda *x, **kw: (kernel_calls.append(1),
                                            real(*x, **kw))[1]

        @jax.jit
        def f(a, b):
            return repro.matmul(a, b, policy="tcec_bf16x6")

        default = NumericsConfig.from_env()
        with numerics.use(force=True, interpret=True, min_dim=0):
            with numerics.use(default):
                f(a, b)               # default recipe: XLA fallback
            assert kernel_calls == []
            f(a, b)                   # outer forced recipe: MUST NOT hit
            assert len(kernel_calls) == 1   # the default-config lowering
    finally:
        ops.tcec_matmul = real


def test_explicit_cfg_governs_tuning(tmp_path):
    """Regression (review finding): a cfg threaded into dispatch/tuning
    governs tune mode and cache path — not the ambient context."""
    from repro.kernels import tuning
    ambient_cache = str(tmp_path / "ambient.json")
    cfg_cache = str(tmp_path / "explicit.json")
    cfg = numerics.active().replace(tune="off", tune_cache=cfg_cache)
    with numerics.use(tune="force", tune_cache=ambient_cache):
        assert not tuning._should_measure(cfg)       # explicit wins
        assert tuning.cache_path(cfg) == cfg_cache
        assert tuning.get_cache(cfg).path == cfg_cache
        blk, meta = tuning.autotune(1, 256, 256, 256, "tcec_bf16x6",
                                    cfg=cfg)
        assert meta["source"] == "heuristic"         # tune=off: no measure
    assert not os.path.exists(ambient_cache)


def test_threaded_cfg_governs_interpret_resolution():
    """Regression (review finding): a cfg threaded into maybe_dispatch
    governs the kernel's interpret-mode resolution all the way down —
    an ambient context must not override it one layer deeper in ops."""
    from repro.core.policy import get_policy
    from repro.kernels import dispatch
    a, b = _rand((128, 128), 32), _rand((128, 128), 33)
    dims = (((1,), (0,)), ((), ()))
    cfg = numerics.active().replace(force=True, min_dim=0)   # interpret=None
    # ambient says compiled (interpret=False) — on CPU that would abort the
    # pallas call; the threaded cfg's auto-resolution (None -> interpret on
    # a non-TPU backend) must win
    with numerics.use(interpret=False):
        out = dispatch.maybe_dispatch(a, b, get_policy("tcec_bf16x6"), dims,
                                      cfg=cfg)
    assert out is not None and out.shape == (128, 128)


def test_invalid_policy_fails_at_config_time():
    """Regression (review finding): a bad policy name fails at the use()
    site with a clear error, not as a bare KeyError at the first verb."""
    with pytest.raises(ValueError, match="unknown policy"):
        with numerics.use(policy="tcec_bf16x"):
            pass
    with pytest.raises(ValueError, match="unknown policy"):
        NumericsConfig(policy=None)
    with pytest.warns(UserWarning, match="not a registered policy"):
        cfg = NumericsConfig.from_env({"REPRO_POLICY": "typo"})
    assert cfg.policy == ENV_VARS["REPRO_POLICY"].default


def test_get_cache_is_per_path(tmp_path):
    """Regression (review finding): interleaving configs with different
    tune_cache paths reuse their own BlockCache instances (no LRU thrash)."""
    from repro.kernels import tuning
    c1 = numerics.active().replace(tune_cache=str(tmp_path / "a.json"))
    c2 = numerics.active().replace(tune_cache=str(tmp_path / "b.json"))
    a1, a2 = tuning.get_cache(c1), tuning.get_cache(c2)
    assert a1 is not a2
    assert tuning.get_cache(c1) is a1 and tuning.get_cache(c2) is a2


def test_config_epoch_interning():
    base = numerics.active()
    assert numerics.config_epoch(base) == 0          # env default = epoch 0
    cfg = base.replace(min_dim=41)
    e1 = numerics.config_epoch(cfg)
    assert e1 != 0
    assert numerics.config_epoch(base.replace(min_dim=41)) == e1  # interned
    assert numerics.config_epoch(base.replace(min_dim=42)) != e1


def test_reload_env_defaults_roundtrip(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_MIN_DIM", "32")
    try:
        assert numerics.reload_env_defaults().min_dim == 32
        assert numerics.active().min_dim == 32
    finally:
        monkeypatch.delenv("REPRO_PALLAS_MIN_DIM")
        numerics.reload_env_defaults()
    assert numerics.active().min_dim == 128


# ------------------------------------------------------- typed env parsers

@pytest.mark.parametrize("off", ["0", "false", "no", "off", "", "  "])
def test_bool_vars_treat_falsy_and_empty_as_off(off):
    env = {"REPRO_FORCE_PALLAS": off, "REPRO_DISABLE_PALLAS": off}
    cfg = NumericsConfig.from_env(env)
    assert not cfg.force and cfg.enabled, off


@pytest.mark.parametrize("on", ["1", "true", "YES", "On"])
def test_bool_vars_truthy_spellings(on):
    cfg = NumericsConfig.from_env({"REPRO_FORCE_PALLAS": on})
    assert cfg.force


def test_bool_garbage_warns_and_uses_default():
    with pytest.warns(UserWarning, match="unrecognized boolean"):
        cfg = NumericsConfig.from_env({"REPRO_DISABLE_PALLAS": "maybe"})
    assert cfg.enabled            # the old truthy-parse would have disabled


def test_int_empty_and_garbage_fall_back_to_default():
    assert NumericsConfig.from_env({"REPRO_PALLAS_MIN_DIM": ""}).min_dim == 128
    assert NumericsConfig.from_env(
        {"REPRO_PALLAS_MIN_DIM": " 64 "}).min_dim == 64
    with pytest.warns(UserWarning, match="unrecognized integer"):
        cfg = NumericsConfig.from_env({"REPRO_PALLAS_MIN_DIM": "soon"})
    assert cfg.min_dim == 128


def test_path_empty_means_default():
    default = ENV_VARS["REPRO_TUNE_CACHE"].default
    assert NumericsConfig.from_env({"REPRO_TUNE_CACHE": ""}).tune_cache \
        == default
    assert NumericsConfig.from_env(
        {"REPRO_TUNE_CACHE": "/tmp/x.json"}).tune_cache == "/tmp/x.json"


def test_tune_mode_mapping_disable_wins():
    assert NumericsConfig.from_env({}).tune == "auto"
    assert NumericsConfig.from_env({"REPRO_TUNE": "1"}).tune == "force"
    assert NumericsConfig.from_env({"REPRO_TUNE_DISABLE": "1"}).tune == "off"
    assert NumericsConfig.from_env(
        {"REPRO_TUNE": "1", "REPRO_TUNE_DISABLE": "1"}).tune == "off"


def test_tuning_honors_tune_mode():
    from repro.kernels import tuning
    with numerics.use(tune="off"):
        assert not tuning._should_measure()
    with numerics.use(tune="force"):
        assert tuning._should_measure()
    with numerics.use(tune="auto"):
        assert tuning._should_measure() == (jax.default_backend() == "tpu")


def test_tune_cache_path_scoped_by_context(tmp_path):
    from repro.kernels import tuning
    p = str(tmp_path / "ctx_tune.json")
    with numerics.use(tune_cache=p):
        assert tuning.cache_path() == p
        assert tuning.get_cache().path == p
    assert tuning.cache_path() == ENV_VARS["REPRO_TUNE_CACHE"].default


def test_cli_override_parsing():
    ov = numerics.parse_override_args(
        ["policy=tcec_bf16x6", "enabled=false", "min_dim=0",
         "block=128,128,256", "paged_block=none"])
    assert ov == {"policy": "tcec_bf16x6", "enabled": False, "min_dim": 0,
                  "block": (128, 128, 256), "paged_block": None}
    with pytest.raises(ValueError):
        numerics.parse_override_args(["min_dim"])          # no '='
    with pytest.raises(ValueError):
        numerics.parse_override_args(["not_a_field=1"])
    with pytest.raises(ValueError):
        numerics.parse_override_args(["force=maybe"])


# ------------------------------------------------------ structural lints

def _src_files():
    for dirpath, _, files in os.walk(os.path.join(ROOT, "src")):
        for fn in files:
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


_ENV_READ = re.compile(r"os\.environ\.get\(|os\.getenv\(|os\.environ\[")
_ENV_WRITE = re.compile(r"os\.environ\[[^]]+\]\s*=")


def test_no_env_reads_outside_registry():
    """The regrowth guard: every environment *read* in src/ must go
    through repro.numerics (writes — e.g. XLA_FLAGS before jax init — are
    allowed)."""
    offenders = []
    for path in _src_files():
        if path.endswith(os.path.join("repro", "numerics.py")):
            continue
        with open(path) as f:
            for lineno, line in enumerate(f, 1):
                code = line.split("#", 1)[0]
                if _ENV_READ.search(code) and not _ENV_WRITE.search(code):
                    offenders.append(f"{os.path.relpath(path, ROOT)}:"
                                     f"{lineno}: {line.strip()}")
    assert not offenders, (
        "environment reads outside the repro.numerics registry:\n"
        + "\n".join(offenders))


def test_every_repro_var_mentioned_in_src_is_registered():
    """Any REPRO_* name appearing anywhere under src/ (code, docstring,
    comment) must be a registered env var — stale or ad-hoc knobs fail."""
    unknown = []
    for path in _src_files():
        with open(path) as f:
            text = f.read()
        for token in set(re.findall(r"\bREPRO_[A-Z0-9_]+\b", text)):
            if token not in ENV_VARS:
                unknown.append(f"{os.path.relpath(path, ROOT)}: {token}")
    assert not unknown, f"unregistered REPRO_* names: {unknown}"


def test_registry_is_well_formed():
    for var in ENV_VARS.values():
        assert var.name.startswith("REPRO_")
        assert var.kind in ("bool", "int", "str", "path")
        assert var.doc
        if var.field is not None and var.name not in ("REPRO_TUNE",
                                                      "REPRO_TUNE_DISABLE"):
            assert var.field in {f.name for f in
                                 __import__("dataclasses").fields(
                                     NumericsConfig)}


def test_examples_and_benchmarks_stay_on_public_surface():
    """Mirror of the CI lint: no deep imports of repro.kernels /
    repro.core.policy outside src/ and tests/."""
    deep = re.compile(r"repro\.kernels|repro\.core\.policy")
    offenders = []
    for sub in ("examples", "benchmarks"):
        for dirpath, _, files in os.walk(os.path.join(ROOT, sub)):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as f:
                    for lineno, line in enumerate(f, 1):
                        if deep.search(line):
                            offenders.append(
                                f"{os.path.relpath(path, ROOT)}:{lineno}")
    assert not offenders, f"deep imports on the public surface: {offenders}"


# ------------------------------------------------------------- verb layer

def test_matmul_verb_batched_and_2d():
    a2, b2 = _rand((64, 32), 6), _rand((32, 16), 7)
    a3, b3 = _rand((2, 64, 32), 8), _rand((2, 32, 16), 9)
    assert repro.matmul(a2, b2).shape == (64, 16)
    assert repro.matmul(a3, b3).shape == (2, 64, 16)
    np.testing.assert_allclose(np.asarray(repro.matmul(a2, b2)),
                               np.asarray(a2) @ np.asarray(b2),
                               rtol=1e-5, atol=1e-5)


def test_einsum_verb_matches_reference():
    a, b = _rand((4, 8, 16), 10), _rand((16, 12), 11)
    out = repro.einsum("bsk,kd->bsd", a, b, policy="fp32")
    ref = np.einsum("bsk,kd->bsd", np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)


def test_attention_verb_defaults_positions_and_dispatches():
    q, k, v = _rand((1, 128, 4, 64), 12), _rand((1, 128, 2, 64), 13), \
        _rand((1, 128, 2, 64), 14)
    base = repro.attention(q, k, v, policy="tcec_bf16x6")
    fused = repro.attention(q, k, v, policy="tcec_bf16x6", force=True,
                            interpret=True, min_dim=0,
                            attn_block=(128, 128))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(base),
                               rtol=2e-6, atol=2e-6)


def test_attention_verb_is_differentiable():
    q, k, v = _rand((1, 128, 2, 64), 15), _rand((1, 128, 2, 64), 16), \
        _rand((1, 128, 2, 64), 17)

    def loss(q):
        return jnp.sum(repro.attention(q, k, v, policy="tcec_bf16x6",
                                       force=True, interpret=True,
                                       min_dim=0,
                                       attn_block=(128, 128)) ** 2)

    def loss_ref(q):
        return jnp.sum(repro.attention(q, k, v, policy="tcec_bf16x6",
                                       enabled=False) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(loss)(q)),
                               np.asarray(jax.grad(loss_ref)(q)),
                               rtol=1e-4, atol=1e-4)


def test_engine_pins_numerics_config():
    """The serving engine snapshots the construction-time config: its
    steps run under that scope even when called from a different ambient
    context."""
    from repro.serving import Engine
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen3-0.6b")
    with numerics.use(min_dim=3):
        engine = Engine(cfg, get_model_params(cfg), max_slots=1,
                        num_pages=16, page_size=4)
    assert engine.numerics_config.min_dim == 3
    # explicit pinning wins over ambient
    pinned = numerics.active().replace(min_dim=9)
    engine2 = Engine(cfg, get_model_params(cfg), max_slots=1, num_pages=16,
                     page_size=4, numerics_config=pinned)
    assert engine2.numerics_config.min_dim == 9


_PARAMS_CACHE = {}


def get_model_params(cfg):
    from repro.models import get_model
    key = cfg.name if hasattr(cfg, "name") else id(cfg)
    if key not in _PARAMS_CACHE:
        _PARAMS_CACHE[key] = get_model(cfg).init(jax.random.PRNGKey(0))
    return _PARAMS_CACHE[key]
