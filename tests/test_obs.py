"""repro.obs: metrics registry, tracing, dispatch explain, numerics health.

Pins the four telemetry layers' contracts:

  * registry semantics — labels, snapshot/diff, thread-safety, and
    reset-keeps-objects (handles stay valid across test resets);
  * span nesting plus Chrome-trace/Perfetto + JSONL export round-trips
    (schema-validated: every event carries name/ph/ts, async request
    events pair ``b``/``e`` by id);
  * dispatch-explain rule slugs — each recorded decline names the rule
    from docs/architecture.md's decision tree (the doc must backtick
    every slug), and every non-fused contraction gets an entry;
  * monitor probe math — ``safe_exponent_range`` and the observed
    (gradual-)underflow fraction against ``core/theory.py``'s closed
    forms (the probe uses round-to-nearest casts where the theory
    assumes RZ, which shifts the closed form by exactly one exponent);
  * the overhead bound — tracing off/on changes nothing about the
    engine's jitted traces (counted), and monitor off leaves the
    contraction jaxpr callback-free.
"""
import json
import os
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import numerics, obs
from repro.core import theory
from repro.core.policy import get_policy, policy_mm
from repro.obs import metrics
from repro.obs import numerics_health as nh
from repro.obs.explain import RULES
from repro.obs.trace import Tracer, current, last, trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ============================================================== registry

def test_counter_labels_and_total():
    c = metrics.counter("test/obs/counter")
    c.reset()
    c.inc(kernel="matmul")
    c.inc(2, kernel="paged")
    c.inc()
    assert c.value(kernel="matmul") == 1
    assert c.value(kernel="paged") == 2
    assert c.value() == 1                      # the unlabeled series
    assert c.total() == 4
    items = c.items()
    assert items["test/obs/counter{kernel=paged}"] == 2
    assert items["test/obs/counter"] == 1


def test_gauge_running_extrema():
    g = metrics.gauge("test/obs/gauge")
    g.reset()
    g.set_min(-3.0)
    g.set_min(-1.0)
    g.set_max(5.0)
    g.set_max(2.0)
    assert g.value() == 5.0                     # last set_max won the slot
    g.set(7.0, policy="x")
    assert g.value(policy="x") == 7.0


def test_histogram_buckets_count_sum_percentile():
    h = metrics.histogram("test/obs/hist", buckets=(1.0, 2.0, 4.0))
    h.reset()
    for v in (0.5, 0.5, 1.5, 3.0, 9.0):         # 9.0 -> overflow slot
        h.observe(v)
    assert h.count() == 5
    assert h.sum() == pytest.approx(14.5)
    items = h.items()["test/obs/hist"]
    assert items["counts"] == [2, 1, 1, 1]      # (0,1], (1,2], (2,4], over
    # interpolated: the 50th percentile lands in the (1, 2] bucket
    assert 1.0 <= h.percentile(50) <= 2.0
    assert h.percentile(100) == 4.0             # capped at the top edge
    assert metrics.histogram("test/obs/empty",
                             buckets=(1.0,)).percentile(99) == 0.0


def test_histogram_label_merge():
    h = metrics.histogram("test/obs/hist2", buckets=(1.0, 2.0))
    h.reset()
    h.observe(0.5, policy="a")
    h.observe(1.5, policy="b")
    assert h.count(policy="a") == 1
    assert h.count() == 2                       # no labels -> merged view


def test_registry_kind_conflict_raises():
    metrics.counter("test/obs/kindconflict")
    with pytest.raises(TypeError):
        metrics.gauge("test/obs/kindconflict")


def test_snapshot_diff_omits_unchanged():
    c = metrics.counter("test/obs/diff")
    c.reset()
    c.inc(5)
    old = metrics.snapshot(include_sources=False)
    c.inc(3)
    metrics.observe("test/obs/diffhist", 0.5, buckets=(1.0,))
    new = metrics.snapshot(include_sources=False)
    d = metrics.diff(new, old)
    assert d["counters"]["test/obs/diff"] == 3
    assert "test/obs/counter" not in d["counters"]   # unchanged -> omitted
    assert d["histograms"]["test/obs/diffhist"]["count"] == 1


def test_default_sources_present():
    import repro.serving.engine  # noqa: F401 — registers its source
    snap = obs.snapshot()
    assert "kernels/guard" in snap["sources"]
    assert "allowed" in snap["sources"]["kernels/guard"]
    assert "faults/fired" in snap["sources"]
    assert "serving/engine" in snap["sources"]


def test_thread_safety():
    c = metrics.counter("test/obs/threads")
    c.reset()
    h = metrics.histogram("test/obs/threadhist", buckets=(0.5, 1.0))
    h.reset()

    def work():
        for _ in range(1000):
            c.inc(site="t")
            h.observe(0.25)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value(site="t") == 8000
    assert h.count() == 8000


def test_reset_keeps_objects_and_sources():
    c = metrics.counter("test/obs/reset")
    c.inc(9)
    obs.reset()
    assert c.value() == 0
    c.inc()                                     # old handle still works
    assert metrics.counter("test/obs/reset") is c
    assert "kernels/guard" in obs.snapshot()["sources"]


# =============================================================== tracing

def test_span_nesting_with_synthetic_clock():
    ticks = iter(range(100))
    tr = Tracer(clock=lambda: next(ticks))      # 1-second ticks
    with tr.span("outer") as args:
        with tr.span("inner"):
            pass
        args["occupancy"] = 3                   # annotated at exit
    inner, outer = tr.events
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert outer["ph"] == "X" and outer["dur"] > inner["dur"]
    assert outer["args"]["occupancy"] == 3      # mutable-dict annotation
    assert inner["ts"] >= outer["ts"]


def test_trace_context_precedence_and_last():
    assert current() is None
    with trace() as t1:
        assert current() is t1
        with trace() as t2:
            assert current() is t2              # innermost wins
        assert current() is t1
    assert current() is None
    assert last() is t1                         # exported after exit


def test_export_roundtrip_chrome_and_jsonl(tmp_path, monkeypatch):
    tr = Tracer(clock=iter(range(100)).__next__)
    tr.async_begin("request", 7, prompt_len=4)
    with tr.span("engine.step", clock=1):
        tr.instant("fallback-rerun", slots=[0])
    tr.async_end("request", 7, finish="length", tokens=8)

    p = tmp_path / "trace.json"
    tr.export(str(p))
    doc = json.loads(p.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    for ev in evs:                              # minimal chrome schema
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
    by_ph = {ev["ph"]: ev for ev in evs}
    assert by_ph["b"]["id"] == by_ph["e"]["id"] == 7
    assert by_ph["e"]["args"]["finish"] == "length"
    assert by_ph["X"]["name"] == "engine.step" and "dur" in by_ph["X"]
    assert by_ph["i"]["s"] == "t"

    pl = tmp_path / "trace.jsonl"
    tr.export(str(pl))
    lines = [json.loads(ln) for ln in pl.read_text().splitlines()]
    assert lines == evs                         # same events, one per line

    # with no tracer ever installed, export has nothing to write
    import sys
    trace_mod = sys.modules["repro.obs.trace"]   # attr is the shadow fn
    monkeypatch.setattr(trace_mod, "_LAST", None)
    with pytest.raises(RuntimeError, match="no tracer"):
        obs.export(str(p))


# ====================================================== dispatch explain

def test_explain_rule_slugs_documented():
    """docs/architecture.md's decision tree must name every rule slug."""
    with open(os.path.join(ROOT, "docs", "architecture.md")) as f:
        doc = f.read()
    for slug in RULES:
        assert f"`{slug}`" in doc, f"rule {slug!r} missing from " \
                                   "docs/architecture.md"


def test_explain_names_declining_rule_per_route():
    obs.reset()
    a, b = _rand((256, 256), 1), _rand((256, 256), 2)
    small = jnp.ones((8, 8), jnp.float32)
    with numerics.use(policy="tcec_bf16x3", force=True, interpret=True):
        policy_mm(a, b)                               # fused
        policy_mm(small, small)                       # below-min-dim
    with numerics.use(policy="tcec_bf16x3", enabled=False):
        policy_mm(a, b)                               # hatch-disabled
    with numerics.use(policy="fp32"):
        policy_mm(a, b)                               # plain-policy
    if jax.default_backend() != "tpu":
        with numerics.use(policy="tcec_bf16x3"):
            policy_mm(a, b)                           # off-backend
    rep = obs.explain()
    rules = {e["rule"] for e in rep.entries}
    expect = {"fused", "below-min-dim", "hatch-disabled", "plain-policy"}
    if jax.default_backend() != "tpu":
        expect.add("off-backend")
    assert expect <= rules, rep.entries
    assert rep.n_fused >= 1 and rep.n_fallback >= 3
    # every non-fused decision names its rule, keyed like the guard
    for e in rep.fallbacks():
        assert e["rule"] in RULES and e["rule"] != "fused"
        assert e["backend"] == jax.default_backend()
        assert e["kernel"] == "matmul"
    # counters carry the same totals
    routes = metrics.counter("kernels/dispatch/route")
    assert routes.value(kernel="matmul", route="fused") == rep.n_fused
    assert (routes.value(kernel="matmul", route="fallback")
            == rep.n_fallback)
    assert str(rep).startswith("dispatch explain:")


def test_explain_policy_ineligible_and_epilogue():
    obs.reset()
    from repro.kernels import dispatch
    pol16 = get_policy("fp16_markidis")
    with numerics.use(policy="fp16_markidis", force=True,
                      fuse_epilogue=True):
        assert not dispatch.epilogue_eligible(pol16)
    with numerics.use(policy="tcec_bf16x6", force=True,
                      fuse_epilogue=True):
        assert dispatch.epilogue_eligible(get_policy("tcec_bf16x6"))
    dec = obs.explain().entries
    epi = [e for e in dec if e["kernel"] == "epilogue"]
    assert {e["rule"] for e in epi} == {"policy-ineligible", "fused"}


def test_explain_report_reset():
    obs.reset()
    from repro.obs.explain import record
    record("matmul", "tcec_bf16x3", (1, 2), "below-min-dim")
    assert obs.explain(reset=True).n_fallback == 1
    assert obs.explain().entries == []
    with pytest.raises(ValueError, match="unknown dispatch rule"):
        record("matmul", "tcec_bf16x3", (), "not-a-rule")


# ======================================================== numerics health

def test_safe_exponent_range_pins_theory():
    """The range's low edge is exactly where the paper's closed-form
    P[u+gu] (Eq. 15) first hits zero."""
    cases = {("bfloat16", 8): (-110, 127),
             ("float16", 11): (-1, 15),
             ("float16", 0): (10, 26)}
    fmts = {"bfloat16": theory.BF16, "float16": theory.FP16}
    for (dtype, sb), expected in cases.items():
        lo, hi = nh.safe_exponent_range(dtype, sb)
        assert (lo, hi) == expected, (dtype, sb)
        fmt = fmts[dtype]
        assert theory.p_underflow_gradual(lo, fmt, sb) == 0.0
        assert theory.p_underflow_gradual(lo - 1, fmt, sb) > 0.0


def test_probe_underflow_fraction_matches_closed_form():
    """Observed gradual-underflow fraction vs Eq. 15.  The probe casts
    round-to-nearest where the closed form assumes RZ, which makes the
    residual one exponent smaller — so the probe at operand exponent
    ``e`` tracks the closed form at ``e - 1``."""
    pol = get_policy("fp16_halfhalf")
    rng = np.random.default_rng(0)
    for e in (-13, -12, -11):
        x = jnp.asarray((2.0 ** e * (1 + rng.random(8192)))
                        .astype(np.float32))
        stats, _, _ = nh._operand_probe(x, pol)
        predicted = theory.p_underflow_gradual(e - 1, theory.FP16,
                                               pol.scale_bits)
        assert float(stats["gu"]) == pytest.approx(predicted, abs=0.02), e
        assert float(stats["oob"]) == 1.0       # e < safe lo = -1
        assert float(stats["emin"]) == e == float(stats["emax"])


def test_probe_healthy_input_is_quiet():
    pol = get_policy("tcec_bf16x3")
    stats, _, _ = nh._operand_probe(_rand((128, 128), 3), pol)
    assert float(stats["gu"]) == 0.0
    assert float(stats["oob"]) == 0.0


def test_monitor_risk_counters_and_output_parity():
    obs.reset()
    x = jnp.asarray((np.random.default_rng(4).standard_normal((128, 128))
                     * 2.0 ** -20).astype(np.float32))
    y = _rand((128, 128), 5)
    with numerics.use(policy="fp16_halfhalf", monitor=True):
        on = policy_mm(x, y)
        on.block_until_ready()
    with numerics.use(policy="fp16_halfhalf"):
        off = policy_mm(x, y)
        off.block_until_ready()
    assert bool(jnp.array_equal(on, off))       # pure observation
    snap = obs.snapshot(include_sources=False)
    risk = metrics.counter("numerics/monitor/underflow_risk")
    assert risk.value(site="mm", policy="fp16_halfhalf") >= 1
    gu = snap["histograms"][
        "numerics/monitor/underflow_frac{policy=fp16_halfhalf}"]
    assert gu["count"] >= 1 and gu["sum"] > 0.5
    assert snap["gauges"][
        "numerics/monitor/exponent_min{policy=fp16_halfhalf}"] < -15


def test_monitor_off_leaves_graph_callback_free():
    a, b = _rand((64, 64), 6), _rand((64, 64), 7)

    def f(a, b):
        return policy_mm(a, b, "fp16_halfhalf")

    with numerics.use(policy="fp16_halfhalf"):
        off = str(jax.make_jaxpr(f)(a, b))
    with numerics.use(policy="fp16_halfhalf", monitor=True):
        on = str(jax.make_jaxpr(f)(a, b))
    assert "callback" not in off
    assert "callback" in on


def test_monitor_sampling_gate():
    nh.configure(sample_every=1000)
    try:
        before = nh._calls
        nh.observe(_rand((8, 8)), _rand((8, 8)),
                   get_policy("tcec_bf16x3"))   # not the sampled call
        assert nh._calls == before + 1
    finally:
        nh.configure(sample_every=1)


def test_monitor_env_knob_registered():
    assert "REPRO_MONITOR" in numerics.ENV_VARS
    cfg = numerics.NumericsConfig.from_env({"REPRO_MONITOR": "1"})
    assert cfg.monitor is True
    assert numerics.NumericsConfig.from_env({}).monitor is False


# ======================================================= engine tracing

_ENGINE_CACHE = {}


def _engine_fixture():
    if not _ENGINE_CACHE:
        from repro.configs import get_smoke_config
        from repro.models import get_model
        cfg = get_smoke_config("qwen3-0.6b")
        model = get_model(cfg)
        _ENGINE_CACHE["v"] = (cfg, model.init(jax.random.PRNGKey(0)))
    return _ENGINE_CACHE["v"]


def _run_engine(n_req=3, max_tokens=4):
    from repro.serving import Engine, SamplingParams
    cfg, params = _engine_fixture()
    engine = Engine(cfg, params, max_slots=4, num_pages=64, page_size=8)
    rng = np.random.default_rng(8)
    for i in range(n_req):
        engine.add_request(rng.integers(0, cfg.vocab_size, 6),
                           SamplingParams(max_tokens=max_tokens, seed=i))
    engine.run()
    return engine


def test_engine_trace_exports_request_lifecycle(tmp_path):
    obs.reset()
    n_req, max_tokens = 3, 4
    with trace() as tr:
        _run_engine(n_req, max_tokens)
    p = tmp_path / "serve.json"
    obs.export(str(p))
    doc = json.loads(p.read_text())
    evs = doc["traceEvents"]
    begins = {e["id"] for e in evs
              if e["ph"] == "b" and e["name"] == "request"}
    ends = {e["id"]: e for e in evs
            if e["ph"] == "e" and e["name"] == "request"}
    assert len(begins) == n_req and begins == set(ends)
    for ev in ends.values():
        assert ev["args"]["finish"] == "length"
        assert ev["args"]["tokens"] == max_tokens
    admitted = [e for e in evs
                if e["ph"] == "n" and e["name"] == "admitted"]
    assert len(admitted) == n_req
    steps = [e for e in evs
             if e["ph"] == "X" and e["name"] == "engine.step"]
    assert steps and all("occupancy" in e["args"] and "clock" in e["args"]
                         for e in steps)
    assert any(e["name"] == "prefill" and e["args"]["batch"] >= 1
               for e in evs if e["ph"] == "X")
    assert any(e["name"] == "decode" for e in evs if e["ph"] == "X")
    # latency histograms were fed while the tracer was active
    assert metrics.histogram("serving/latency/ttft_s").count() == n_req
    assert metrics.histogram("serving/latency/queue_wait_s").count() == n_req
    assert metrics.histogram("serving/latency/tpot_s").count() > 0
    assert tr is last()


def test_tracing_off_is_inert_and_adds_no_traces(monkeypatch):
    """With no tracer installed the engine writes no spans and no latency
    samples; and tracing on adds ZERO extra jitted traces — all
    instrumentation is host-side (counted via the decode trace hook)."""
    from repro.serving import engine as eng_mod
    obs.reset()
    counts = []
    orig = eng_mod._decode_and_sample

    def counting(*a, **kw):
        counts.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(eng_mod, "_decode_and_sample", counting)
    _run_engine()                               # tracing off
    untraced = len(counts)
    assert metrics.histogram("serving/latency/ttft_s").count() == 0
    counts.clear()
    with trace() as tr:
        _run_engine()                           # tracing on, same config
    assert len(counts) == untraced              # zero extra jitted traces
    assert metrics.histogram("serving/latency/ttft_s").count() == 3
    assert any(e["name"] == "engine.step" for e in tr.events)


def test_engine_stats_folded_into_snapshot():
    engine = _run_engine()
    src = obs.snapshot()["sources"]["serving/engine"]
    assert src["decode_steps"] >= engine.n_decode_steps
    assert src["prefills"] >= engine.n_prefills


# =============================================================== cli glue

def test_cli_session_exports(tmp_path, capsys):
    import argparse
    obs.reset()
    ap = argparse.ArgumentParser()
    obs.add_cli_flags(ap)
    tr_path = str(tmp_path / "t.json")
    m_path = str(tmp_path / "m.json")
    args = ap.parse_args(["--trace", tr_path, "--metrics-out", m_path])
    with obs.cli_session(args):
        tr = current()
        assert tr is not None
        tr.instant("tick")
    out = capsys.readouterr().out
    assert "telemetry: trace ->" in out
    assert "telemetry: metrics ->" in out
    assert "dispatch explain:" in out
    assert json.loads(open(tr_path).read())["traceEvents"]
    assert "counters" in json.loads(open(m_path).read())
