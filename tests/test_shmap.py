"""Sharded TCEC dispatch (kernels/shmap.py): plan construction, mesh-aware
routing + kernel-call counters, the shard_map knob, per-shard tuning keys,
and multi-device fused-vs-fallback parity (2-/4-/8-way CPU meshes in a
subprocess with a forced device count, like test_distribution.py)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import repro
from repro import numerics
from repro.kernels import dispatch, shmap, tuning
from repro.parallel import ctx


class FakeMesh:
    """Shape-only mesh stand-in for plan computation (no devices)."""
    def __init__(self, **shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _one_device_mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


# ---------------------------------------------------------------- plans

def test_matmul_plan_prefers_n_then_k_then_m():
    mesh = FakeMesh(data=1, model=4)
    # all divisible -> N (column parallel)
    plan = shmap.matmul_plan((256, 256), (256, 256), mesh)
    assert plan.sharded_dim == "N" and not plan.psum_axes
    assert plan.b_spec == P(None, "model") and plan.out_spec == P(None, "model")
    assert plan.local == (1, 256, 64, 256)
    # N indivisible -> K (row parallel: local fold then f32 psum)
    plan = shmap.matmul_plan((256, 256), (256, 129), mesh)
    assert plan.sharded_dim == "K" and plan.psum_axes == ("model",)
    assert plan.a_spec == P(None, "model") and plan.b_spec == P("model", None)
    assert plan.out_spec == P(None, None)
    # N and K indivisible -> M
    plan = shmap.matmul_plan((256, 131), (131, 129), mesh)
    assert plan.sharded_dim == "M"
    assert plan.a_spec == P("model", None) and plan.out_spec == P("model", None)
    # nothing divisible -> unsupported
    assert shmap.matmul_plan((130, 131), (131, 129), mesh) is None


def test_matmul_plan_batch_and_dp_axes():
    mesh = FakeMesh(pod=2, data=2, model=2)
    plan = shmap.matmul_plan((8, 256, 256), (8, 256, 256), mesh)
    assert plan.a_spec == P(("pod", "data"), None, None)
    assert plan.b_spec == P(("pod", "data"), None, "model")
    assert plan.local == (2, 256, 128, 256)
    # 2-D under dp axes: M takes them
    plan = shmap.matmul_plan((256, 256), (256, 256), mesh)
    assert plan.a_spec == P(("pod", "data"), None)
    # indivisible batch AND M -> unsupported
    assert shmap.matmul_plan((3, 129, 256), (3, 256, 256), mesh) is None


def test_plans_reject_unknown_axis_names():
    mesh = FakeMesh(expert=2)
    assert shmap.matmul_plan((256, 256), (256, 256), mesh) is None
    assert shmap.attention_plan((1, 256, 4, 64), (1, 256, 2, 64),
                                mesh) is None
    assert shmap.paged_plan((2, 8, 64), (9, 8, 2, 64), mesh) is None
    # size-1 unknown axes never block
    assert shmap.matmul_plan((256, 256), (256, 256),
                             FakeMesh(expert=1, model=2)) is not None


def test_attention_plan_heads_then_qseq():
    mesh = FakeMesh(data=2, model=2)
    # Hkv divisible -> head sharding (whole GQA groups per device)
    plan = shmap.attention_plan((2, 256, 8, 64), (2, 256, 4, 64), mesh)
    assert plan.mode == "heads"
    assert plan.q_spec == P("data", None, "model", None)
    assert plan.k_spec == P("data", None, "model", None)
    assert plan.local == (1, 2, 256, 256)
    # Hkv indivisible, S divisible -> q-sequence sharding, K/V replicated
    plan = shmap.attention_plan((2, 256, 3, 64), (2, 256, 1, 64), mesh)
    assert plan.mode == "qseq"
    assert plan.q_spec == P("data", "model", None, None)
    assert plan.k_spec == P("data", None, None, None)
    assert plan.qp_spec == P("data", "model")    # global offsets ride along
    assert plan.local == (1, 1, 128, 256)
    # neither divisible -> unsupported
    assert shmap.attention_plan((2, 251, 3, 64), (2, 251, 1, 64),
                                mesh) is None
    # batch indivisible by the dp axes -> unsupported
    assert shmap.attention_plan((3, 256, 8, 64), (3, 256, 4, 64),
                                mesh) is None


def test_paged_plan_heads_on_model_tables_local():
    mesh = FakeMesh(data=2, model=2)
    plan = shmap.paged_plan((2, 8, 64), (9, 8, 4, 64), mesh)
    assert plan.pool_spec == P(None, None, "model", None)
    assert plan.bt_spec == P("data", None)       # device-local block tables
    assert plan.len_spec == P("data")
    assert plan.local == (1, 2)
    assert shmap.paged_plan((2, 8, 64), (9, 8, 3, 64), mesh) is None


# ----------------------------------------------- routing + counters (1 dev)

def test_matmul_routes_through_shard_map_under_mesh():
    a, b = _rand((128, 128), 0), _rand((128, 128), 1)
    with numerics.use(force=True, interpret=True, min_dim=0,
                      block=(128, 128, 128)):
        ref = repro.matmul(a, b, policy="tcec_bf16x6")
        n0 = shmap.counters()["matmul"]
        with ctx.use_mesh(_one_device_mesh()):
            out = repro.matmul(a, b, policy="tcec_bf16x6")
        assert shmap.counters()["matmul"] == n0 + 1
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_shard_map_knob_declines_to_xla_under_mesh():
    from repro.kernels import ops
    a, b = _rand((128, 128), 2), _rand((128, 128), 3)
    calls = []
    real = ops.tcec_matmul
    try:
        ops.tcec_matmul = lambda *x, **kw: (calls.append(1),
                                            real(*x, **kw))[1]
        with numerics.use(force=True, interpret=True, min_dim=0,
                          shard_map=False):
            with ctx.use_mesh(_one_device_mesh()):
                out = repro.matmul(a, b, policy="tcec_bf16x6")
        assert calls == []                       # kernel never ran
        with numerics.use(enabled=False):
            xla = repro.matmul(a, b, policy="tcec_bf16x6")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(xla))
    finally:
        ops.tcec_matmul = real


def test_unsupported_spec_declines_to_xla():
    """The decline path: a mesh whose model axis divides nothing must fall
    back to the XLA expansion (GSPMD shards that natively)."""
    a = _rand((2, 128, 128), 4)
    b = _rand((2, 128, 128), 5)
    dims = (((2,), (1,)), ((0,), (0,)))
    pol = repro.get_policy("tcec_bf16x6")
    with numerics.use(force=True, interpret=True, min_dim=0):
        assert dispatch.decide(a, b, pol, dims) is not None
        with ctx.use_mesh(FakeMesh(model=3)):
            assert dispatch.decide(a, b, pol, dims) is None
            assert dispatch.maybe_dispatch(a, b, pol, dims) is None


def test_dp_over_model_context_declines():
    """When the installed context declares "model" a *batch* axis
    (dp_over_model: pure DP, params replicated), the plan builders would
    misassign it to N/K/M and force an entry all-gather — dispatch must
    decline to the XLA fallback instead."""
    a, b = _rand((256, 256), 8), _rand((256, 256), 9)
    dims = (((1,), (0,)), ((), ()))
    pol = repro.get_policy("tcec_bf16x6")
    mesh = _one_device_mesh()
    with numerics.use(force=True, interpret=True, min_dim=0):
        with ctx.use_mesh(mesh):                      # default batch axes
            assert dispatch.decide(a, b, pol, dims) is not None
        with ctx.use_mesh(mesh, ("data", "model")):   # dp_over_model
            assert dispatch.decide(a, b, pol, dims) is None
            q = _rand((1, 128, 4, 64), 10)
            k = _rand((1, 128, 2, 64), 11)
            assert not dispatch.attention_eligible(q, k, k,
                                                   policy="tcec_bf16x6")


def test_pool_spec_head_dim_fallback():
    """Engine pool layout: KV heads on model when divisible, else
    head_dim (the parallel/sharding.py cache convention), else
    replicated — pool capacity scales with TP either way."""
    from repro.serving.engine import _pool_spec
    assert _pool_spec((9, 8, 4, 64), FakeMesh(data=2, model=2)) \
        == P(None, None, "model", None)
    assert _pool_spec((9, 8, 2, 64), FakeMesh(data=1, model=4)) \
        == P(None, None, None, "model")      # Hkv=2 < msize=4 -> head_dim
    assert _pool_spec((9, 8, 3, 7), FakeMesh(data=1, model=4)) \
        == P(None, None, None, None)     # nothing divides -> replicated


def test_epilogue_fusion_declines_under_mesh():
    pol = repro.get_policy("tcec_bf16x6")
    with numerics.use(force=True, interpret=True, fuse_epilogue=True):
        assert dispatch.epilogue_eligible(pol)
        with ctx.use_mesh(_one_device_mesh()):
            assert not dispatch.epilogue_eligible(pol)


# ----------------------------------------------------- per-shard tuning keys

def test_shmap_tuning_namespace_keys():
    assert tuning.cache_key(1, 128, 128, 128, "tcec_bf16x6", "cpu",
                            namespace=shmap.NAMESPACE) \
        == "cpu/shmap/tcec_bf16x6/b1_m128_n128_k128"
    assert tuning.attn_cache_key(1, 2, 4, 128, 256, 64, 64, "tcec_bf16x6",
                                 "cpu", True, shmap.NAMESPACE) \
        .startswith("cpu/shmap/attn/")
    assert tuning.paged_cache_key(1, 2, 4, 4, 8, 64, 64, "tcec_bf16x6",
                                  "cpu", shmap.NAMESPACE) \
        .startswith("cpu/shmap/paged/")
    # shmap keys never collide with the global namespace for the same shape
    assert tuning.cache_key(1, 128, 128, 128, "tcec_bf16x6", "cpu") \
        != tuning.cache_key(1, 128, 128, 128, "tcec_bf16x6", "cpu",
                            namespace=shmap.NAMESPACE)


def test_mesh_dispatch_tunes_the_local_tile(tmp_path):
    """A mesh-routed matmul measures/records under backend/shmap/... keyed
    by the per-shard shape, not the global one."""
    cache = str(tmp_path / "tune.json")
    a, b = _rand((128, 128), 6), _rand((128, 128), 7)
    with numerics.use(force=True, interpret=True, min_dim=0, tune="force",
                      tune_cache=cache):
        with ctx.use_mesh(_one_device_mesh()):
            repro.matmul(a, b, policy="tcec_bf16x6")
    import json
    entries = json.load(open(cache))["entries"]
    assert any(k.startswith("cpu/shmap/tcec_bf16x6/") for k in entries), \
        sorted(entries)


# --------------------------------------------------------------- env knob

def test_repro_shard_map_registered_and_round_trips(monkeypatch):
    """Regrowth-guard extension: the knob is in the registry, feeds the
    NumericsConfig field, and round-trips through the env defaults."""
    var = numerics.ENV_VARS["REPRO_SHARD_MAP"]
    assert var.field == "shard_map" and var.kind == "bool"
    assert var.default is True
    assert numerics.NumericsConfig().shard_map is True
    monkeypatch.setenv("REPRO_SHARD_MAP", "0")
    assert not numerics.reload_env_defaults().shard_map
    monkeypatch.delenv("REPRO_SHARD_MAP")
    assert numerics.reload_env_defaults().shard_map


# -------------------------------------------------- sharded model entry

def test_sharded_train_step_runs_and_routes_fused_attention(tmp_path):
    """train(mesh=...) jits the sharded step and — with dispatch forced —
    exercises the fused attention route under the mesh (counter asserts
    it), the acceptance hook for the training wiring."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataConfig
    from repro.optim import adamw
    from repro.train.loop import TrainLoopConfig, train
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke_config("qwen3-0.6b")
    # all devices on the model axis: works at any forced device count
    # (Hkv=2 falls back to q-sequence sharding when model > 2)
    mesh = make_host_mesh(model=len(jax.devices()))
    n0 = shmap.counters()["attention"]
    with numerics.use(force=True, interpret=True):
        state, hist = train(cfg, adamw.OptConfig(lr=1e-3),
                            DataConfig(seed=0, global_batch=2, seq_len=128),
                            TrainLoopConfig(total_steps=1, ckpt_every=100),
                            str(tmp_path), mesh=mesh, log=lambda m: None)
    assert np.isfinite(hist[-1]["loss"])
    assert shmap.counters()["attention"] > n0     # fused route fired in the step


def test_engine_under_mesh_matches_unsharded_greedy():
    """Continuous-batching engine under a mesh (sharded pool layout, paged
    kernel via shard_map) stays token-identical to the unsharded engine."""
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.serving import Engine, SamplingParams
    cfg = get_smoke_config("qwen3-0.6b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, 5)),
               list(rng.integers(0, cfg.vocab_size, 9))]
    sp = SamplingParams(temperature=0.0, max_tokens=5)
    nc = numerics.active().replace(force=True, interpret=True)
    base = Engine(cfg, params, max_slots=2, numerics_config=nc).run(
        prompts, sp)
    n0 = shmap.counters()["paged"]
    with ctx.use_mesh(_one_device_mesh()):
        eng = Engine(cfg, params, max_slots=2, numerics_config=nc)
    out = eng.run(prompts, sp)     # mesh captured at construction
    assert eng.mesh is not None
    assert shmap.counters()["paged"] > n0
    assert list(base.values()) == list(out.values())


# ------------------------------------------- multi-device parity battery
#
# One subprocess with 8 forced CPU devices runs the whole battery: 2-, 4-,
# and 8-way meshes; matmul M/N/K-sharded; attention head- and
# q-sequence-sharded (incl. causal+window mask offsets); paged decode.

SUBPROC_BATTERY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    import repro
    from repro import numerics
    from repro.kernels import shmap
    from repro.parallel import ctx

    def rand(shape, seed):
        return jnp.asarray(
            np.random.default_rng(seed).standard_normal(shape), jnp.float32)

    mesh2 = jax.make_mesh((1, 2), ("data", "model"))
    mesh4 = jax.make_mesh((2, 2), ("data", "model"))
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))

    with numerics.use(force=True, interpret=True, min_dim=0,
                      block=(128, 128, 128), attn_block=(128, 128),
                      paged_block=2):
        cfg = numerics.active()

        # ---- matmul: N-, K-, M-, and batch-sharded --------------------
        a, b = rand((256, 256), 0), rand((256, 256), 1)
        ref = repro.matmul(a, b, policy="tcec_bf16x6")
        for mesh, tag in ((mesh2, "2way"), (mesh8, "8way")):
            plan = shmap.matmul_plan(a.shape, b.shape, mesh)
            assert plan.sharded_dim in ("N",), (tag, plan)
            with ctx.use_mesh(mesh):
                out = repro.matmul(a, b, policy="tcec_bf16x6")
            assert np.array_equal(np.asarray(out), np.asarray(ref)), tag

        ak, bk = rand((4, 131, 256), 2), rand((4, 256, 129), 3)
        plan = shmap.matmul_plan(ak.shape, bk.shape, mesh4)
        assert plan.sharded_dim == "K" and plan.psum_axes == ("model",)
        refk = repro.matmul(ak, bk, policy="tcec_bf16x6")
        with ctx.use_mesh(mesh4):
            outk = repro.matmul(ak, bk, policy="tcec_bf16x6")
        # K sharding: f32 psum AFTER the local fold — f32-level agreement,
        # not bit equality (documented reduction-order change)
        err = float(jnp.max(jnp.abs(outk - refk)))
        scale = float(jnp.max(jnp.abs(refk)))
        assert err <= 1e-5 * max(scale, 1.0), err
        with numerics.use(enabled=False):
            xlak = repro.matmul(ak, bk, policy="tcec_bf16x6")
        assert float(jnp.max(jnp.abs(outk - xlak))) <= 1e-5 * max(scale, 1.0)

        am, bm = rand((256, 131), 4), rand((131, 129), 5)
        plan = shmap.matmul_plan(am.shape, bm.shape, mesh2)
        assert plan.sharded_dim == "M"
        refm = repro.matmul(am, bm, policy="tcec_bf16x6")
        with ctx.use_mesh(mesh2):
            outm = repro.matmul(am, bm, policy="tcec_bf16x6")
        assert np.array_equal(np.asarray(outm), np.asarray(refm))

        # ---- attention: head- and q-sequence-sharded ------------------
        q = rand((2, 256, 8, 64), 6)
        k = rand((2, 256, 4, 64), 7)
        v = rand((2, 256, 4, 64), 8)
        refa = repro.attention(q, k, v, policy="tcec_bf16x6", window=37,
                               softcap=20.0)
        plan = shmap.attention_plan(q.shape, k.shape, mesh8)
        assert plan.mode == "heads", plan
        n0 = shmap.counters()["attention"]
        with ctx.use_mesh(mesh8):
            outa = repro.attention(q, k, v, policy="tcec_bf16x6", window=37,
                                   softcap=20.0)
        assert shmap.counters()["attention"] == n0 + 1
        assert np.array_equal(np.asarray(outa), np.asarray(refa))

        q1 = rand((2, 256, 2, 64), 9)          # Hkv=1: forces qseq on 4-way
        k1 = rand((2, 256, 1, 64), 10)
        v1 = rand((2, 256, 1, 64), 11)
        mesh_q = jax.make_mesh((2, 4), ("data", "model"))
        plan = shmap.attention_plan(q1.shape, k1.shape, mesh_q)
        assert plan.mode == "qseq", plan
        refq = repro.attention(q1, k1, v1, policy="tcec_bf16x6", window=37)
        with ctx.use_mesh(mesh_q):
            outq = repro.attention(q1, k1, v1, policy="tcec_bf16x6",
                                   window=37)
        # causal + window masks offset by the shard's global position:
        # bit-identical per shard to the unsharded kernel
        assert np.array_equal(np.asarray(outq), np.asarray(refq))
        with numerics.use(enabled=False):
            xlaq = repro.attention(q1, k1, v1, policy="tcec_bf16x6",
                                   window=37)
        assert float(jnp.max(jnp.abs(outq - xlaq))) < 2e-6

        # ---- paged decode ---------------------------------------------
        from repro import tcec_paged_attention
        from repro.kernels import dispatch as kd
        rng = np.random.default_rng(12)
        B, Hkv, rep, hd, ps, maxp, NP = 2, 4, 2, 64, 8, 4, 9
        qd = rand((B, Hkv * rep, hd), 13)
        kp = jnp.asarray(rng.standard_normal((NP, ps, Hkv, hd)), jnp.bfloat16)
        vp = jnp.asarray(rng.standard_normal((NP, ps, Hkv, hd)), jnp.bfloat16)
        bt = jnp.asarray(rng.permutation(8).reshape(B, maxp) + 1, jnp.int32)
        lens = jnp.asarray([25, 30], jnp.int32)
        refp = kd.attention_decode(qd, kp, vp, bt, lens,
                                   policy="tcec_bf16x6", window=17)
        assert refp is not None
        n0 = shmap.counters()["paged"]
        with ctx.use_mesh(mesh8):
            outp = kd.attention_decode(qd, kp, vp, bt, lens,
                                       policy="tcec_bf16x6", window=17)
        assert outp is not None and shmap.counters()["paged"] == n0 + 1
        assert np.array_equal(np.asarray(outp), np.asarray(refp))

        # ---- 4-way sharded train step exercises the fused route -------
        from repro.configs import get_smoke_config
        from repro.data.pipeline import DataConfig
        from repro.optim import adamw
        from repro.train.loop import TrainLoopConfig, train
        import tempfile
        cfg_m = get_smoke_config("qwen3-0.6b")
        n0 = shmap.counters()["attention"]
        with numerics.use(min_dim=128, block=None, attn_block=(128, 128)):
            with tempfile.TemporaryDirectory() as d:
                state, hist = train(
                    cfg_m, adamw.OptConfig(lr=1e-3),
                    DataConfig(seed=0, global_batch=4, seq_len=128),
                    TrainLoopConfig(total_steps=1, ckpt_every=100),
                    d, mesh=mesh4, log=lambda m: None)
        assert np.isfinite(hist[-1]["loss"])
        assert shmap.counters()["attention"] > n0
        # params really sharded on the mesh
        shardings = {s for leaf in jax.tree.leaves(state["params"])
                     for s in [leaf.sharding]}
        assert any(not s.is_fully_replicated for s in shardings)

    print("OK")
""")


def test_sharded_parity_battery_subprocess():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", SUBPROC_BATTERY],
                       capture_output=True, text=True, cwd=root,
                       timeout=900)
    assert "OK" in r.stdout, (r.stdout[-2000:], r.stderr[-4000:])
